"""Unit tests for the paper's core: averaging math, Algorithm 2 controller,
QSGD, comm model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import AveragingConfig
from repro.core import averaging as avg
from repro.core import qsgd
from repro.core.comm_model import (method_comm, ring_allreduce_bytes,
                                   roofline_terms, speedup_vs_fullsgd)
from repro.core.controller import (ADPSGDController, ConstantPeriodController,
                                   DecreasingPeriodController,
                                   FullSyncController, make_controller)
from repro.optim import get_optimizer

KEY = jax.random.PRNGKey(0)


def quad_loss(params, batch):
    """Simple quadratic: ||w - target||^2 with per-sample noise."""
    d = params["w"] - batch["target"].mean(0)
    loss = jnp.sum(d * d)
    return loss, {"ce_loss": loss}


def make_quad(R=4, dim=8):
    params = {"w": jnp.zeros((dim,))}
    W = avg.stack_replicas(params, R)
    return params, W


class TestAveraging:
    def test_stack_and_mean_roundtrip(self):
        params, W = make_quad()
        leaves = jax.tree_util.tree_leaves(W)
        assert all(x.shape[0] == 4 for x in leaves)
        back = avg.replica_mean(W)
        np.testing.assert_allclose(back["w"], params["w"])

    def test_variance_zero_when_identical(self):
        _, W = make_quad()
        assert float(avg.parameter_variance(W)) == 0.0

    def test_variance_formula(self):
        W = {"w": jnp.array([[1.0, 0.0], [3.0, 0.0]])}
        # mean = 2; dev = 1 each; Var = (1 + 1)/2 = 1
        assert float(avg.parameter_variance(W)) == pytest.approx(1.0)

    def test_sync_produces_mean_and_sk(self):
        W = {"w": jnp.array([[1.0, 2.0], [3.0, 4.0]])}
        Ws, _, sk = avg.sync_replicas(W)
        np.testing.assert_allclose(Ws["w"], [[2.0, 3.0], [2.0, 3.0]])
        assert float(sk) == pytest.approx(2.0)  # (1+1+1+1)/2

    def test_sync_kernel_path_matches(self):
        W = {"a": jax.random.normal(KEY, (4, 33)),
             "b": jax.random.normal(jax.random.fold_in(KEY, 1), (4, 5, 7))}
        W1, _, sk1 = avg.sync_replicas(W)
        W2, _, sk2 = avg.sync_replicas(W, use_kernel=True)
        for k in W:
            np.testing.assert_allclose(W1[k], W2[k], atol=1e-6)
        np.testing.assert_allclose(sk1, sk2, rtol=1e-5)

    def test_local_step_keeps_replicas_independent(self):
        opt = get_optimizer("sgd")
        step = avg.make_local_step(quad_loss, opt)
        _, W = make_quad(R=2, dim=2)
        opt_state = jax.vmap(opt.init)(W)
        batch = {"target": jnp.stack([jnp.ones((4, 2)), -jnp.ones((4, 2))])}
        W2, _, m = step(W, opt_state, batch, jnp.float32(0.1))
        # replica 0 moves toward +1, replica 1 toward -1
        assert float(W2["w"][0, 0]) > 0 > float(W2["w"][1, 0])
        assert float(avg.parameter_variance(W2)) > 0

    def test_full_step_keeps_replicas_identical(self):
        opt = get_optimizer("momentum")
        step = avg.make_full_step(quad_loss, opt)
        _, W = make_quad(R=2, dim=2)
        opt_state = jax.vmap(opt.init)(W)
        batch = {"target": jnp.stack([jnp.ones((4, 2)), -jnp.ones((4, 2))])}
        W2, opt2, _ = step(W, opt_state, batch, jnp.float32(0.1))
        assert float(avg.parameter_variance(W2)) < 1e-12

    def test_local_step_n1_equals_full_step(self):
        opt = get_optimizer("momentum")
        local = avg.make_local_step(quad_loss, opt)
        full = avg.make_full_step(quad_loss, opt)
        params = {"w": jax.random.normal(KEY, (3,))}
        W = avg.stack_replicas(params, 1)
        st = jax.vmap(opt.init)(W)
        batch = {"target": jax.random.normal(KEY, (1, 4, 3))}
        W1, _, _ = local(W, st, batch, jnp.float32(0.05))
        W2, _, _ = full(W, st, batch, jnp.float32(0.05))
        np.testing.assert_allclose(W1["w"], W2["w"], atol=1e-7)

    def test_group_sync(self):
        W = {"w": jnp.arange(8.0).reshape(4, 2)}
        Wg = avg.group_sync(W, 2)
        np.testing.assert_allclose(
            Wg["w"], [[1.0, 2.0], [1.0, 2.0], [5.0, 6.0], [5.0, 6.0]])


class TestControllers:
    def cfg(self, **kw):
        base = dict(method="adpsgd", p_init=4, p_const=8,
                    k_sample_frac=0.1, warmup_full_sync_steps=0)
        base.update(kw)
        return AveragingConfig(**base)

    def test_full_sync_every_step(self):
        c = FullSyncController(self.cfg(method="fullsgd"), 100)
        assert all(c.sync_now(k) for k in range(10))

    def test_constant_period(self):
        c = ConstantPeriodController(self.cfg(method="cpsgd"), 100)
        syncs = [k for k in range(32) if c.sync_now(k)]
        assert syncs == [7, 15, 23, 31]

    def test_warmup_syncs_every_step(self):
        c = ADPSGDController(self.cfg(warmup_full_sync_steps=5), 100)
        assert all(c.sync_now(k) for k in range(5))

    def test_adpsgd_samples_c2_then_adapts_up(self):
        # constant S_k/lr during sampling -> C2 = that ratio; then feed
        # small S_k -> period must increase (Algorithm 2 line 16-17)
        c = ADPSGDController(self.cfg(k_sample_frac=0.2), total_steps=100)
        k = 0
        while k < 20:                      # sampling window (K_s = 20)
            if c.sync_now(k):
                c.observe(k, 0.1, 0.05)    # S_k/lr = 0.5
            k += 1
        assert c.c2 == pytest.approx(0.5)
        p0 = c.period
        while k < 60:
            if c.sync_now(k):
                c.observe(k, 0.1, 0.01)    # S_k << 0.7 * lr * C2
            k += 1
        assert c.period > p0

    def test_adpsgd_adapts_down_and_respects_pmin(self):
        c = ADPSGDController(self.cfg(k_sample_frac=0.1, p_init=3), 100)
        for k in range(10):
            if c.sync_now(k):
                c.observe(k, 0.1, 0.05)
        for k in range(10, 100):
            if c.sync_now(k):
                c.observe(k, 0.1, 10.0)    # S_k >> 1.3 * lr * C2
        assert c.period == 1               # clamped at p_min

    def test_adpsgd_dead_band_keeps_period(self):
        c = ADPSGDController(self.cfg(k_sample_frac=0.1), 100)
        for k in range(10):
            if c.sync_now(k):
                c.observe(k, 0.1, 0.05)
        p0 = c.period
        for k in range(10, 50):
            if c.sync_now(k):
                c.observe(k, 0.1, 0.05)    # S_k == lr*C2: inside dead band
        assert c.period == p0

    def test_decreasing_controller(self):
        cfg = self.cfg(method="decreasing", decreasing_p0=10, decreasing_p1=2)
        c = DecreasingPeriodController(cfg, 100)
        early = [k for k in range(50) if c.sync_now(k)]
        late = [k for k in range(50, 100) if c.sync_now(k)]
        assert len(late) > len(early)

    def test_make_controller_dispatch(self):
        for m in ["adpsgd", "cpsgd", "fullsgd", "qsgd", "decreasing"]:
            assert make_controller(self.cfg(method=m), 10) is not None


class TestQSGD:
    def test_quantize_unbiased(self):
        x = jnp.array([0.3, -0.7, 1.1, 0.0])
        keys = jax.random.split(KEY, 2000)
        dq = jax.vmap(lambda k: qsgd.dequantize(
            *qsgd.quantize(x, k, 8), 8))(keys)
        np.testing.assert_allclose(dq.mean(0), x, atol=5e-3)

    def test_qsgd_step_keeps_replicas_identical(self):
        opt = get_optimizer("momentum")
        step = qsgd.make_qsgd_step(quad_loss, opt, bits=8)
        params = {"w": jax.random.normal(KEY, (5,))}
        W = avg.stack_replicas(params, 4)
        st = jax.vmap(opt.init)(W)
        batch = {"target": jax.random.normal(KEY, (4, 8, 5))}
        W2, _, _ = step(W, st, batch, jnp.float32(0.1), KEY)
        assert float(avg.parameter_variance(W2)) < 1e-12


class TestCommModel:
    def test_ring_allreduce_bytes(self):
        assert ring_allreduce_bytes(100, 2) == pytest.approx(400.0)

    def test_periodic_beats_full(self):
        full = method_comm("fullsgd", int(1e7), 16, 1000, 1000, 1e9)
        adp = method_comm("adpsgd", int(1e7), 16, 1000, 125, 1e9)
        assert adp.time_s < full.time_s / 7

    def test_qsgd_quarter_bytes(self):
        full = method_comm("fullsgd", int(1e6), 16, 10, 10, 1e9)
        q = method_comm("qsgd", int(1e6), 16, 10, 10, 1e9)
        assert q.bytes_per_node == pytest.approx(full.bytes_per_node / 4)

    def test_speedup_increases_when_bandwidth_drops(self):
        s100 = speedup_vs_fullsgd("adpsgd", int(25e6), 16, 4000, 498,
                                  0.1, 100e9 / 8)
        s10 = speedup_vs_fullsgd("adpsgd", int(25e6), 16, 4000, 498,
                                 0.1, 10e9 / 8)
        assert s10 > s100 > 1.0

    def test_roofline_dominant(self):
        r = roofline_terms(1e15, 1e12, 1e14, 256)
        assert r["dominant"] == "collective"
        r = roofline_terms(1e18, 1e12, 1e10, 256)
        assert r["dominant"] == "compute"
