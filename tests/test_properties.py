"""Property-based tests (hypothesis) on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # listed in requirements.txt; optional here
from hypothesis import given, settings, strategies as stf  # noqa: E402
from jax.sharding import AbstractMesh, PartitionSpec as P  # noqa: E402

from repro.configs import AveragingConfig, ModelConfig  # noqa: E402
from repro.configs.base import ParallelismPlan  # noqa: E402
from repro.core import averaging as avg
from repro.core import qsgd
from repro.core.controller import ADPSGDController, ConstantPeriodController
from repro.launch import sharding as sh  # noqa: E402

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

finite_f = stf.floats(-100, 100, allow_nan=False, width=32)


@given(stf.integers(1, 8), stf.integers(1, 50), stf.randoms())
def test_sync_idempotent(R, dim, rnd):
    W = {"w": jnp.asarray(np.random.RandomState(rnd.randint(0, 2**31))
                          .randn(R, dim).astype(np.float32))}
    W1, _, sk1 = avg.sync_replicas(W)
    W2, _, sk2 = avg.sync_replicas(W1)
    np.testing.assert_allclose(W1["w"], W2["w"], atol=1e-6)
    assert float(sk2) <= 1e-8  # second sync sees zero variance


@given(stf.integers(2, 8), stf.integers(1, 40), stf.randoms())
def test_sync_preserves_global_mean(R, dim, rnd):
    x = np.random.RandomState(rnd.randint(0, 2**31)).randn(R, dim)
    W = {"w": jnp.asarray(x.astype(np.float32))}
    W1, _, _ = avg.sync_replicas(W)
    np.testing.assert_allclose(np.asarray(W1["w"]).mean(0), x.mean(0),
                               atol=1e-5)


@given(stf.integers(2, 8), stf.randoms())
def test_variance_nonnegative_and_scale_quadratic(R, rnd):
    x = np.random.RandomState(rnd.randint(0, 2**31)).randn(R, 16)
    W = {"w": jnp.asarray(x.astype(np.float32))}
    v1 = float(avg.parameter_variance(W))
    v2 = float(avg.parameter_variance({"w": 2.0 * W["w"]}))
    assert v1 >= 0
    np.testing.assert_allclose(v2, 4 * v1, rtol=1e-4, atol=1e-6)


@given(stf.integers(1, 64), stf.integers(2, 8), stf.randoms())
def test_qsgd_error_bound(n, bits, rnd):
    rs = np.random.RandomState(rnd.randint(0, 2**31))
    x = jnp.asarray(rs.randn(n).astype(np.float32) * 10)
    key = jax.random.PRNGKey(rnd.randint(0, 2**31))
    lv, norm = qsgd.quantize(x, key, bits)
    dq = qsgd.dequantize(lv, norm, bits)
    s = (1 << (bits - 1)) - 1
    assert float(jnp.max(jnp.abs(dq - x))) <= float(norm) / s + 1e-5
    # levels stay within int8-representable range for bits<=8
    assert int(jnp.abs(lv.astype(jnp.int32)).max()) <= s


@given(stf.integers(1, 30), stf.integers(1, 200))
def test_constant_controller_sync_count(p, steps):
    cfg = AveragingConfig(method="cpsgd", p_const=p,
                          warmup_full_sync_steps=0)
    c = ConstantPeriodController(cfg, steps)
    syncs = sum(c.sync_now(k) for k in range(steps))
    assert syncs == steps // p


@given(stf.lists(stf.floats(1e-6, 1e3), min_size=1, max_size=60),
       stf.floats(1e-4, 1.0))
def test_adpsgd_period_always_valid(sks, lr):
    cfg = AveragingConfig(method="adpsgd", p_init=4, k_sample_frac=0.2,
                          p_min=1, p_max=64)
    c = ADPSGDController(cfg, total_steps=100)
    k = 0
    for s in sks:
        while not c.sync_now(k):
            k += 1
        c.observe(k, lr, s)
        assert cfg.p_min <= c.period <= cfg.p_max
        k += 1


@given(stf.integers(2, 6), stf.integers(1, 3), stf.randoms())
def test_group_sync_partitions(R_half, group_pow, rnd):
    R = 2 * R_half
    g = min(2 ** group_pow, R)
    while R % g:
        g //= 2
    x = np.random.RandomState(rnd.randint(0, 2**31)).randn(R, 8)
    W = {"w": jnp.asarray(x.astype(np.float32))}
    Wg = avg.group_sync(W, g)
    out = np.asarray(Wg["w"])
    for i in range(0, R, g):
        # within-group equality; group mean preserved
        np.testing.assert_allclose(out[i:i + g],
                                   np.broadcast_to(x[i:i + g].mean(0), (g, 8)),
                                   atol=1e-5)
    # cross-group variance survives (outer sync is separate)
    if R > g:
        assert float(avg.parameter_variance(Wg)) >= 0


@given(stf.integers(1, 4), stf.integers(4, 32), stf.randoms())
def test_optimizers_reduce_quadratic(R, dim, rnd):
    from repro.optim import get_optimizer
    rs = np.random.RandomState(rnd.randint(0, 2**31))
    target = jnp.asarray(rs.randn(dim).astype(np.float32))

    def loss_fn(p, b):
        d = p["w"] - target
        return jnp.sum(d * d), {}

    for name in ("sgd", "momentum", "adamw"):
        opt = get_optimizer(name)
        params = {"w": jnp.zeros((dim,))}
        st = opt.init(params)
        l0 = float(loss_fn(params, None)[0])
        g = jax.grad(lambda p: loss_fn(p, None)[0])
        lr = 0.05 if name != "adamw" else 0.05
        for _ in range(30):
            params, st = opt.update(g(params), st, params, jnp.float32(lr))
        assert float(loss_fn(params, None)[0]) < l0


# ---------------------------------------------------------------------------
# base_spec divisibility guards (launch/sharding.py): a dim is sharded only
# if the mesh axis divides it; odd sizes fall back to replication, and every
# produced PartitionSpec must be valid for the mesh.
# ---------------------------------------------------------------------------


def _abstract_mesh(sizes, names):
    try:
        return AbstractMesh(tuple(zip(names, sizes)))
    except TypeError:
        return AbstractMesh(sizes, names)


def _check_spec_valid(spec, shape, mesh):
    """GSPMD validity: named axes exist, appear at most once across the
    spec, and divide the dim they shard."""
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    seen = []
    assert len(spec) <= len(shape)
    for dim, entry in zip(shape, tuple(spec) + (None,) * len(shape)):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        for a in axes:
            assert a in sizes, (spec, mesh.axis_names)
            assert a not in seen, f"axis {a} used twice in {spec}"
            seen.append(a)
        total = int(np.prod([sizes[a] for a in axes]))
        assert dim % total == 0, (spec, shape, sizes)


# paths drawn from the real rule table: megatron projections, embeddings,
# MoE experts, CNN fc/conv — plus an unmatched path (catch-all replication)
_PATHS_2D = ["embed", "lm_head", "wq|w", "wo|w", "w_up|w", "w_down|w",
             "fc1|w", "fc2|w", "mystery|w"]


@given(stf.sampled_from(_PATHS_2D),
       stf.integers(1, 4099), stf.integers(1, 515),
       stf.sampled_from([2, 3, 4, 8, 16]))
def test_base_spec_divisibility_guard(path, d0, d1, m):
    mesh = _abstract_mesh((4, m), ("data", "model"))
    plan = ParallelismPlan(plan="replica_dp", placement="replica_tp")
    spec = sh.base_spec(ModelConfig(), path, (d0, d1), mesh, plan)
    _check_spec_valid(spec, (d0, d1), mesh)
    # odd sizes on *both* dims -> full fallback to replication
    if d0 % m and d1 % m:
        assert all(s is None for s in spec), (path, spec)


@given(stf.integers(1, 4099), stf.sampled_from([2, 4, 8, 16]))
def test_vocab_parallel_embed_falls_back(vocab, m):
    """Odd vocab sizes fall back from vocab-parallel to d-model sharding
    (and to replication when d_model is odd too)."""
    mesh = _abstract_mesh((4, m), ("data", "model"))
    plan = ParallelismPlan(plan="replica_dp")
    d_model = 8 * m
    spec = sh.base_spec(ModelConfig(), "embed", (vocab, d_model), mesh, plan)
    if vocab % m == 0:
        assert spec == ("model", None)
    else:
        assert spec == (None, "model")
    _check_spec_valid(spec, (vocab, d_model), mesh)


@given(stf.integers(2, 9), stf.integers(1, 129), stf.integers(1, 129),
       stf.sampled_from([2, 4, 8]), stf.booleans())
def test_param_specs_always_valid_for_mesh(R_pow, d0, d1, m, two_pod):
    """Stacked param_specs over a pytree with odd/even dims stay valid for
    1- and 2-pod meshes under the replica_tp plan; the leading entry is
    always the replica-axis entry."""
    R = 4 * R_pow      # replica-axis divisibility is bind()'s runtime guard,
    #                    not param_specs' — keep R a multiple of the 4
    #                    replica devices both meshes have
    mesh = (_abstract_mesh((2, 2, m), ("pod", "data", "model")) if two_pod
            else _abstract_mesh((4, m), ("data", "model")))
    rep = ("pod", "data") if two_pod else ("data",)
    tree = {"fc1": {"w": np.zeros((R, d0, d1)), "b": np.zeros((R, d1))},
            "odd": {"w": np.zeros((R, d0))}}
    specs = sh.param_specs(ModelConfig(), tree, mesh,
                           ParallelismPlan(plan="replica_dp",
                                           placement="replica_tp"),
                           replica_axes=rep, stacked=True)
    flat_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda s: isinstance(s, P))
    flat_x = jax.tree_util.tree_leaves(tree)
    for spec, x in zip(flat_s, flat_x):
        assert spec[0] == (rep if len(rep) > 1 else rep[0])
        _check_spec_valid(spec, x.shape, mesh)
