"""Integration: training loop behaviour matches the paper's claims at small
scale; data pipeline determinism; checkpoint roundtrip."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.io import (controller_state, load_checkpoint,
                                 restore_controller, save_checkpoint)
from repro.configs import AveragingConfig
from repro.core.controller import ADPSGDController
from repro.data.pipeline import SyntheticImages, SyntheticTokens
from repro.models.cnn import cnn_loss, init_cnn
from repro.optim import get_optimizer, make_lr_schedule
from repro.runtime.loop import train_periodic


@pytest.fixture(scope="module")
def cnn_setup():
    data = SyntheticImages(n_samples=256, seed=0)
    params0 = init_cnn(jax.random.PRNGKey(0), widths=(8, 16))
    opt = get_optimizer("momentum")
    lr_fn = make_lr_schedule("step", 0.05, 40, decay_steps=(25,))
    return data, params0, opt, lr_fn


def run(method, cnn_setup, steps=40, **kw):
    data, params0, opt, lr_fn = cnn_setup
    cfg = AveragingConfig(method=method, p_init=2, p_const=4,
                          k_sample_frac=0.3, warmup_full_sync_steps=2, **kw)
    return train_periodic(
        loss_fn=cnn_loss, optimizer=opt, params0=params0, n_replicas=4,
        data_fn=data.batches(n_replicas=4, per_replica_batch=8),
        lr_fn=lr_fn, avg_cfg=cfg, total_steps=steps, track_variance_every=4)


def test_all_methods_decrease_loss(cnn_setup):
    for m in ("fullsgd", "cpsgd", "adpsgd"):
        h = run(m, cnn_setup)
        assert np.mean(h.losses[-5:]) < h.losses[0] * 0.8, m


def test_fullsgd_zero_variance(cnn_setup):
    h = run("fullsgd", cnn_setup, steps=20)
    assert all(v < 1e-10 for v in h.variances)


def test_periodic_has_variance_between_syncs(cnn_setup):
    h = run("cpsgd", cnn_setup, steps=20)
    assert max(h.variances) > 0


def test_adpsgd_records_sk_and_periods(cnn_setup):
    h = run("adpsgd", cnn_setup)
    assert len(h.s_k) == h.n_syncs == len(h.sync_steps)
    assert all(s >= 0 for s in h.s_k)
    assert all(p >= 1 for p in h.period_history)


def test_adpsgd_fewer_syncs_than_fullsgd(cnn_setup):
    h = run("adpsgd", cnn_setup)
    assert h.n_syncs < 40


def test_variance_drops_after_lr_decay(cnn_setup):
    """Paper Fig 1: V_t ~ gamma^2 — the LR drop at step 25 must pull the
    inter-sync variance down."""
    h = run("cpsgd", cnn_setup, steps=40)
    pre = [v for s, v in zip(h.variance_steps, h.variances) if 12 <= s < 24]
    post = [v for s, v in zip(h.variance_steps, h.variances) if s >= 32]
    assert pre and post
    assert np.mean(post) < np.mean(pre)


# ---------------------------------------------------------------------------


def test_token_pipeline_deterministic():
    a = SyntheticTokens(64, 32, n_samples=64, seed=3)
    b = SyntheticTokens(64, 32, n_samples=64, seed=3)
    fa = a.batches(n_replicas=2, per_replica_batch=4)
    fb = b.batches(n_replicas=2, per_replica_batch=4)
    for step in (0, 1, 7, 31):
        np.testing.assert_array_equal(fa(step)["tokens"], fb(step)["tokens"])


def test_pipeline_epoch_reshuffles():
    d = SyntheticImages(n_samples=64, seed=0)
    f = d.batches(n_replicas=2, per_replica_batch=4)
    spe = f.steps_per_epoch
    e0 = np.asarray(f(0)["labels"]).ravel()
    e1 = np.asarray(f(spe)["labels"]).ravel()
    assert not np.array_equal(e0, e1)


def test_pipeline_shards_disjoint_within_step():
    d = SyntheticImages(n_samples=128, seed=0)
    f = d.batches(n_replicas=4, per_replica_batch=8)
    imgs = np.asarray(f(0)["images"])
    flat = imgs.reshape(32, -1)
    assert len({hash(r.tobytes()) for r in flat}) == 32  # no duplicates


def test_checkpoint_roundtrip(tmp_path):
    params = {"a": jnp.arange(6.0).reshape(2, 3),
              "blocks": [{"w": jnp.ones((4,))}, {"w": jnp.zeros((2, 2))}]}
    opt = {"m": {"a": jnp.ones((2, 3)) * 0.5,
                 "blocks": [{"w": jnp.zeros((4,))}, {"w": jnp.ones((2, 2))}]}}
    cfg = AveragingConfig(method="adpsgd")
    ctrl = ADPSGDController(cfg, 100)
    ctrl.p, ctrl.c2, ctrl.n_c2 = 7, 1.25, 3
    path = str(tmp_path / "ckpt")
    save_checkpoint(path, params, opt_state=opt, step=42,
                    controller_state=controller_state(ctrl))
    p2, o2, meta = load_checkpoint(path)
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_allclose(x, y), params, p2)
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_allclose(x, y), opt, o2)
    assert meta["step"] == 42
    c2 = ADPSGDController(cfg, 100)
    restore_controller(c2, meta["controller"])
    assert (c2.p, c2.c2, c2.n_c2) == (7, 1.25, 3)


def test_lr_schedules():
    f = make_lr_schedule("step", 0.1, 100, decay_steps=(50, 75))
    assert f(0) == 0.1 and f(60) == pytest.approx(0.01)
    assert f(80) == pytest.approx(0.001)
    w = make_lr_schedule("wsd", 1.0, 100, warmup_steps=10, decay_frac=0.2)
    assert w(0) < w(9) and w(50) == 1.0 and w(99) < 0.2
    c = make_lr_schedule("cosine", 1.0, 100)
    assert c(0) == pytest.approx(1.0) and c(99) < 0.2
