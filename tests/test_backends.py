"""ExecutionBackend layer: registry, vmap/mesh parity, cross-backend
checkpoint resume, qsgd_periodic anchor persistence, and the adacomm/dasgd
strategies.

This module is backend-count agnostic: under the default suite jax sees one
CPU device (the mesh backend degenerates to a 1-device mesh); the CI job
re-runs it with ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so
the same assertions cover a genuinely sharded replica axis.  The subprocess
test forces 8 devices regardless of the parent's platform.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.backends import (MeshBackend, VmapBackend, available_backends,
                            get_backend_cls, make_backend, resolve_backend)
from repro.checkpoint.io import (load_checkpoint, save_checkpoint,
                                 strategy_state)
from repro.configs import AveragingConfig
from repro.data.pipeline import SyntheticImages
from repro.models.cnn import cnn_loss, init_cnn
from repro.optim import get_optimizer, make_lr_schedule
from repro.runtime.engine import TrainerEngine
from repro.strategies import available_strategies, make_strategy

STEPS = 24
REPLICAS = 8


@pytest.fixture(scope="module")
def setup8():
    data = SyntheticImages(n_samples=256, seed=0)
    params0 = init_cnn(jax.random.PRNGKey(0), widths=(8, 16))
    opt = get_optimizer("momentum")
    lr_fn = make_lr_schedule("step", 0.05, STEPS, decay_steps=(14,))
    return data, params0, opt, lr_fn


def make_engine(setup8, method, backend="vmap", steps=STEPS, batch=4,
                **cfg_kw):
    data, params0, opt, lr_fn = setup8
    base = dict(method=method, p_init=2, p_const=4, k_sample_frac=0.25,
                warmup_full_sync_steps=2)
    base.update(cfg_kw)
    cfg = AveragingConfig(**base)
    return TrainerEngine(
        loss_fn=cnn_loss, optimizer=opt, params0=params0,
        n_replicas=REPLICAS,
        data_fn=data.batches(n_replicas=REPLICAS, per_replica_batch=batch),
        lr_fn=lr_fn, avg_cfg=cfg, total_steps=steps, backend=backend)


# ---------------------------------------------------------------------------
# Registry / resolution
# ---------------------------------------------------------------------------


def test_backend_registry():
    assert "vmap" in available_backends()
    assert "mesh" in available_backends()
    assert get_backend_cls("vmap") is VmapBackend
    assert get_backend_cls("mesh") is MeshBackend
    with pytest.raises(KeyError):
        make_backend("nope")


def test_resolve_backend():
    assert isinstance(resolve_backend(None), VmapBackend)
    assert isinstance(resolve_backend("mesh"), MeshBackend)
    b = VmapBackend()
    assert resolve_backend(b) is b
    with pytest.raises(TypeError):
        resolve_backend(42)


def test_mesh_bind_divisibility():
    b = make_backend("mesh")
    b.bind(REPLICAS)        # 8 divides any forced host device count we use
    assert b.n_replicas == REPLICAS
    if b.n_replica_devices > 1:
        with pytest.raises(ValueError, match="not divisible"):
            make_backend("mesh").bind(b.n_replica_devices + 1)


def test_default_kernel_policy_off_host():
    # use_kernel=None resolves to "profitable only": off everywhere but TPU
    assert VmapBackend().use_kernel == (jax.default_backend() == "tpu")
    assert VmapBackend(use_kernel=True).use_kernel is True


# ---------------------------------------------------------------------------
# vmap / mesh parity (in-process; CI re-runs this file with 8 forced devices)
# ---------------------------------------------------------------------------


def test_adpsgd_mesh_matches_vmap(setup8):
    hv = make_engine(setup8, "adpsgd", "vmap").run()
    hm = make_engine(setup8, "adpsgd", "mesh").run()
    assert hm.sync_steps == hv.sync_steps
    assert hm.period_history == hv.period_history
    np.testing.assert_allclose(hm.losses, hv.losses, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(hm.s_k, hv.s_k, rtol=1e-3, atol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(hm.final_W),
                    jax.tree_util.tree_leaves(hv.final_W)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_mesh_replica_axis_is_sharded(setup8):
    e = make_engine(setup8, "cpsgd", "mesh", steps=4)
    e.run()
    leaf = jax.tree_util.tree_leaves(e.W)[0]
    ndev = e.backend.n_replica_devices
    assert not leaf.sharding.is_fully_replicated or ndev == 1
    assert e.backend.describe()["n_devices"] == len(jax.devices())


@pytest.mark.parametrize("method", ["fullsgd", "qsgd", "hier_adpsgd",
                                    "qsgd_periodic", "dasgd", "adacomm"])
def test_strategies_train_on_mesh(setup8, method):
    h = make_engine(setup8, method, "mesh", steps=16, inner_period=2,
                    group_size=2).run()
    assert len(h.losses) == 16
    assert np.isfinite(h.losses).all()
    assert np.mean(h.losses[-4:]) < h.losses[0]
    assert h.n_syncs > 0


# ---------------------------------------------------------------------------
# Cross-backend checkpoint resume
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("save_bk,resume_bk", [("vmap", "mesh"),
                                               ("mesh", "vmap")])
def test_cross_backend_resume(setup8, tmp_path, save_bk, resume_bk):
    """A checkpoint saved under one backend resumes under the other and
    continues the uninterrupted schedule and loss trajectory."""
    h_full = make_engine(setup8, "adpsgd", "vmap").run()

    half = make_engine(setup8, "adpsgd", save_bk)
    half.run(num_steps=STEPS // 2)
    path = str(tmp_path / "xbk")
    save_checkpoint(path, half.W, opt_state=half.opt_state, step=STEPS // 2,
                    controller_state=strategy_state(half.strategy))

    resumed = make_engine(setup8, "adpsgd", resume_bk)
    W, opt_state, meta = load_checkpoint(path)
    for x in jax.tree_util.tree_leaves(W):
        assert isinstance(np.asarray(x), np.ndarray)   # host arrays on disk
    resumed.load_state(W, opt_state, strategy_state=meta["controller"])
    h_res = resumed.run(start_step=STEPS // 2)

    tail = [s for s in h_full.sync_steps if s >= STEPS // 2]
    assert h_res.sync_steps == tail
    np.testing.assert_allclose(h_res.losses, h_full.losses[STEPS // 2:],
                               rtol=2e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# qsgd_periodic anchor persistence (satellite)
# ---------------------------------------------------------------------------


def test_qsgd_periodic_anchor_rides_checkpoint(setup8, tmp_path):
    """The full-precision anchor is saved and restored, so a resumed run
    continues quantized exchanges bit-for-bit with the uninterrupted run
    instead of paying a full-precision reseed sync."""
    h_full = make_engine(setup8, "qsgd_periodic").run()

    half = make_engine(setup8, "qsgd_periodic")
    half.run(num_steps=STEPS // 2)
    assert half.strategy._anchor is not None       # warmup seeded it
    state = strategy_state(half.strategy)
    assert "anchor" in state["_arrays"]
    path = str(tmp_path / "qp")
    save_checkpoint(path, half.W, opt_state=half.opt_state, step=STEPS // 2,
                    controller_state=state)
    assert os.path.exists(os.path.join(path, "strategy_arrays.npz"))

    resumed = make_engine(setup8, "qsgd_periodic")
    W, opt_state, meta = load_checkpoint(path)
    resumed.load_state(W, opt_state, strategy_state=meta["controller"])
    # the fix: the anchor is installed before the first post-resume sync
    assert resumed.strategy._anchor is not None
    for a, b in zip(jax.tree_util.tree_leaves(resumed.strategy._anchor),
                    jax.tree_util.tree_leaves(half.strategy._anchor)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    h_res = resumed.run(start_step=STEPS // 2)
    np.testing.assert_allclose(h_res.losses, h_full.losses[STEPS // 2:],
                               rtol=1e-6)
    np.testing.assert_allclose(
        h_res.s_k, h_full.s_k[-len(h_res.s_k):] if h_res.s_k else [],
        rtol=1e-6)


# ---------------------------------------------------------------------------
# adacomm / dasgd (satellites)
# ---------------------------------------------------------------------------


def test_new_strategies_registered():
    for name in ("adacomm", "dasgd"):
        assert name in available_strategies()


def test_adacomm_tightens_period_as_loss_falls(setup8):
    e = make_engine(setup8, "adacomm", "vmap", p_init=4, adacomm_interval=8)
    h = e.run()
    c = e.strategy.controller
    assert h.n_syncs > 0
    # loss fell -> sqrt(F/F0) < 1 -> tau never exceeds tau0, and the
    # schedule was actually recomputed after the calibration block
    assert c.f0 is not None
    assert 1 <= c.tau <= c.tau0


def test_adacomm_state_roundtrip():
    cfg = AveragingConfig(method="adacomm", p_init=4, adacomm_interval=4)
    s = make_strategy(cfg, 40)
    for k in range(12):
        s.observe_loss(k, 4.0 - 0.2 * k)
    state = strategy_state(s)
    s2 = make_strategy(cfg, 40)
    from repro.checkpoint.io import restore_strategy
    restore_strategy(s2, state)
    assert s2.controller.tau == s.controller.tau
    assert s2.controller.f0 == pytest.approx(s.controller.f0)


def test_dasgd_schedules_delayed_apply():
    cfg = AveragingConfig(method="dasgd", p_const=4,
                          warmup_full_sync_steps=0, dasgd_delay=2)
    s = make_strategy(cfg, 40)
    acts = {k: s.actions(k) for k in range(12)}
    assert acts[3] == ("step", "sync")               # snapshot
    assert acts[5] == ("step", "sync_apply")         # applied 2 steps later
    assert acts[7] == ("step", "sync")
    assert acts[9] == ("step", "sync_apply")
    assert s.n_comm_events == 3                      # k=3,7,11 snapshots


def test_dasgd_delay_clamped_below_period():
    cfg = AveragingConfig(method="dasgd", p_const=4, dasgd_delay=99)
    assert make_strategy(cfg, 40).delay == 3


def test_dasgd_resume_with_pending_correction(setup8, tmp_path):
    """Checkpointing mid-flight (snapshot taken, correction not yet
    applied) persists the pending delta + due step and resumes exactly."""
    h_full = make_engine(setup8, "dasgd", "vmap").run()

    # warmup=2, p_const=4, delay=2: first steady-state snapshot at k=5,
    # applied at k=7 — stop at step 6 with the correction in flight
    half = make_engine(setup8, "dasgd", "vmap")
    half.run(num_steps=6)
    assert half.strategy._pending is not None
    assert half.strategy._apply_at == 7
    path = str(tmp_path / "dsg")
    save_checkpoint(path, half.W, opt_state=half.opt_state, step=6,
                    controller_state=strategy_state(half.strategy))

    resumed = make_engine(setup8, "dasgd", "vmap")
    W, opt_state, meta = load_checkpoint(path)
    resumed.load_state(W, opt_state, strategy_state=meta["controller"])
    assert resumed.strategy._apply_at == 7
    assert resumed.strategy._pending is not None
    h_res = resumed.run(start_step=6)
    np.testing.assert_allclose(h_res.losses, h_full.losses[6:], rtol=1e-6)


# ---------------------------------------------------------------------------
# Forced 8-device parity (acceptance criterion) — own interpreter because
# device count is fixed at first jax init
# ---------------------------------------------------------------------------

_PARITY_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import jax, numpy as np
assert len(jax.devices()) == 8, jax.devices()
from repro.configs import AveragingConfig
from repro.data.pipeline import SyntheticImages
from repro.models.cnn import cnn_loss, init_cnn
from repro.optim import get_optimizer, make_lr_schedule
from repro.runtime.engine import TrainerEngine

data = SyntheticImages(n_samples=256, seed=0)
params0 = init_cnn(jax.random.PRNGKey(0), widths=(8, 16))
opt = get_optimizer("momentum")
lr_fn = make_lr_schedule("step", 0.05, 14, decay_steps=(8,))

def run(backend):
    cfg = AveragingConfig(method="adpsgd", p_init=2, k_sample_frac=0.25,
                          warmup_full_sync_steps=2)
    e = TrainerEngine(loss_fn=cnn_loss, optimizer=opt, params0=params0,
                      n_replicas=8,
                      data_fn=data.batches(n_replicas=8, per_replica_batch=4),
                      lr_fn=lr_fn, avg_cfg=cfg, total_steps=14,
                      backend=backend)
    h = e.run()
    return h, e

hv, _ = run("vmap")
hm, em = run("mesh")
assert em.backend.n_replica_devices == 8
leaf = jax.tree_util.tree_leaves(em.W)[0]
assert len(leaf.sharding.device_set) == 8, leaf.sharding
assert hm.sync_steps == hv.sync_steps
assert hm.period_history == hv.period_history
np.testing.assert_allclose(hm.losses, hv.losses, rtol=1e-4, atol=1e-5)
np.testing.assert_allclose(hm.s_k, hv.s_k, rtol=1e-3, atol=1e-5)
print("PARITY8 OK")
"""


def test_mesh8_parity_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep \
        + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", _PARITY_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=420)
    assert r.returncode == 0, (r.stdout, r.stderr)
    assert "PARITY8 OK" in r.stdout
