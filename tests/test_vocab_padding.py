"""Vocab padding (hillclimb D1): padded models are semantically identical —
padded columns can never be predicted or scored."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models import model as M

KEY = jax.random.PRNGKey(0)


def _cfgs():
    cfg = reduced(get_config("minicpm-2b").model, vocab_size=117)
    return cfg, dataclasses.replace(cfg, vocab_pad_multiple=16)


def test_padded_vocab_rounding():
    cfg, cfg_p = _cfgs()
    assert cfg.padded_vocab() == 117
    assert cfg_p.padded_vocab() == 128
    assert get_config("minicpm-2b").model.vocab_size % 16 != 0  # the motivation


def test_padded_columns_masked_and_finite_loss():
    _, cfg_p = _cfgs()
    params = M.init_params(KEY, cfg_p)
    toks = jax.random.randint(KEY, (2, 32), 0, 117)
    logits, _ = M.forward(params, {"tokens": toks}, cfg_p)
    assert logits.shape[-1] == 128
    assert float(logits[..., 117:].max()) < -1e29
    loss, _ = M.lm_loss(params, {"tokens": toks}, cfg_p)
    assert np.isfinite(float(loss))


def test_decode_never_selects_padding():
    _, cfg_p = _cfgs()
    params = M.init_params(KEY, cfg_p)
    caches = M.init_caches(cfg_p, 2, 8, dtype=jnp.float32)
    toks = jax.random.randint(KEY, (2, 1), 0, 117)
    for _ in range(4):
        lg, caches = M.decode_step(params, {"tokens": toks}, caches, cfg_p)
        toks = jnp.argmax(lg[:, -1], -1)[:, None]
        assert int(toks.max()) < 117


def test_unpadded_default_everywhere():
    for arch in ("olmo-1b", "glm4-9b"):
        m = get_config(arch).model
        assert m.padded_vocab() == m.vocab_size
