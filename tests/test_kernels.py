"""Per-kernel correctness: sweep shapes/dtypes, assert_allclose vs ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.param_variance import mean_and_sqdev
from repro.kernels.qsgd_quant import dequantize, quantize

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("B,S,H,K,d", [
    (1, 128, 4, 4, 64),
    (2, 256, 4, 2, 32),
    (1, 384, 6, 3, 128),
    (2, 128, 8, 1, 64),       # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [0, 64])
def test_flash_attention(B, S, H, K, d, dtype, window):
    ks = jax.random.split(jax.random.fold_in(KEY, S * H + window), 3)
    q = jax.random.normal(ks[0], (B, S, H, d), dtype)
    k = jax.random.normal(ks[1], (B, S, K, d), dtype)
    v = jax.random.normal(ks[2], (B, S, K, d), dtype)
    out = flash_attention(q, k, v, causal=True, window=window, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("block_q,block_k", [(64, 64), (128, 64), (64, 128)])
def test_flash_attention_blocks(block_q, block_k):
    q = jax.random.normal(KEY, (1, 256, 4, 64))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 256, 2, 64))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (1, 256, 2, 64))
    out = flash_attention(q, k, v, causal=True, block_q=block_q,
                          block_k=block_k, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("n", [7, 1000, 1024, 4097])
@pytest.mark.parametrize("bits", [4, 8])
def test_qsgd_quantize(n, bits):
    x = jax.random.normal(jax.random.fold_in(KEY, n), (n,)) * 3.0
    u = jax.random.uniform(jax.random.fold_in(KEY, n + 1), (n,))
    lv, nm = quantize(x, u, bits=bits, interpret=True)
    lr, nr = ref.quantize_ref(x, u, bits=bits)
    np.testing.assert_array_equal(np.asarray(lv), np.asarray(lr))
    np.testing.assert_allclose(nm, nr, rtol=1e-6)
    dq = dequantize(lv, nm, bits=bits, interpret=True)
    np.testing.assert_allclose(dq, ref.dequantize_ref(lr, nr, bits=bits),
                               rtol=1e-6)
    # quantization error bound: |q - x| <= norm / s elementwise
    s = (1 << (bits - 1)) - 1
    assert float(jnp.max(jnp.abs(dq - x))) <= float(nm) / s + 1e-6


def test_qsgd_multidim_and_zero():
    x = jax.random.normal(KEY, (33, 17))
    u = jax.random.uniform(jax.random.fold_in(KEY, 3), (33, 17))
    lv, nm = quantize(x, u, interpret=True)
    assert lv.shape == x.shape
    z = jnp.zeros((128,))
    lvz, nmz = quantize(z, jnp.zeros((128,)), interpret=True)
    assert float(nmz) == 0.0
    assert int(jnp.abs(lvz).max()) == 0


@pytest.mark.parametrize("R,shape", [(2, (100,)), (8, (33, 7)), (16, (1024,)),
                                     (4, (5, 4, 3))])
def test_param_variance(R, shape):
    w = jax.random.normal(jax.random.fold_in(KEY, R), (R,) + shape)
    m, sq = mean_and_sqdev(w, interpret=True)
    mr, sr = ref.mean_and_sqdev_ref(w)
    np.testing.assert_allclose(m, mr, atol=1e-6)
    np.testing.assert_allclose(sq, sr, rtol=1e-5, atol=1e-6)


def test_param_variance_identical_replicas():
    w = jnp.broadcast_to(jax.random.normal(KEY, (50,)), (8, 50))
    _, sq = mean_and_sqdev(w, interpret=True)
    assert float(sq) < 1e-10


def test_ops_wrappers_run_on_cpu():
    q = jax.random.normal(KEY, (1, 128, 2, 32))
    out = ops.flash_attention(q, q, q)
    assert out.shape == q.shape
    m, sq = ops.param_mean_and_sqdev(jnp.ones((4, 64)))
    assert float(sq) == 0.0
