"""CollectiveOp IR (DESIGN.md §8): descriptor pricing, the byte-true QSGD
exchange, real DaSGD overlap, and the sampled WallClock.

The invariants:

* pricing derives from the op descriptor alone — the old ``PROGRAM_COMM``
  table and the strategies' ``comm_collective()`` hook are gone;
* the byte-true quantized exchange (int8 levels + per-tensor norms,
  dequantized at the receiver) is **bit-matched** across backends and
  placements: the probe S_k and the post-sync parameters agree exactly,
  because every backend reduces the same gathered levels the same way;
* an ``overlap=True`` op never advances the step path's clock at dispatch;
  its cost is settled at fetch as the un-overlapped remainder, and the
  Timeline carries the overlap + fetch records the acceptance criterion
  asks for;
* a mid-flight DaSGD checkpoint (snapshot dispatched, not yet fetched)
  resumes exactly: same losses, and the in-flight probe is reported at its
  snapshot step by the resumed run — half + resumed histories reassemble
  the uninterrupted one with no gap and no duplicate;
* ``WallClock(sample_every=N)`` blocks only on every N-th step, flags the
  in-between records as interpolated, and still accounts the real elapsed
  time.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.backends import make_backend
from repro.backends.ops import (CollectiveOp, InFlightOp, all_mean_op,
                                inner_mean_op, qsgd_step_op,
                                quantized_all_mean_op)
from repro.checkpoint.io import (load_checkpoint, save_checkpoint,
                                 strategy_state)
from repro.configs import AveragingConfig
from repro.core.comm_model import ring_allreduce_bytes
from repro.data.pipeline import SyntheticImages
from repro.models.cnn import cnn_loss, init_cnn
from repro.optim import get_optimizer, make_lr_schedule
from repro.runtime.clock import SimulatedClock, WallClock, make_clock
from repro.runtime.engine import TrainerEngine
from repro.strategies import make_strategy

STEPS = 16
REPLICAS = 8


@pytest.fixture(scope="module")
def setup8():
    data = SyntheticImages(n_samples=256, seed=0)
    params0 = init_cnn(jax.random.PRNGKey(0), widths=(8, 16))
    opt = get_optimizer("momentum")
    lr_fn = make_lr_schedule("step", 0.05, STEPS, decay_steps=(10,))
    return data, params0, opt, lr_fn


def make_engine(setup8, method, backend="vmap", steps=STEPS, clock=None,
                callbacks=(), **cfg_kw):
    data, params0, opt, lr_fn = setup8
    base = dict(method=method, p_init=2, p_const=4, k_sample_frac=0.25,
                warmup_full_sync_steps=2)
    base.update(cfg_kw)
    if isinstance(backend, tuple):
        backend = make_backend(backend[0], placement=backend[1])
    return TrainerEngine(
        loss_fn=cnn_loss, optimizer=opt, params0=params0,
        n_replicas=REPLICAS,
        data_fn=data.batches(n_replicas=REPLICAS, per_replica_batch=4),
        lr_fn=lr_fn, avg_cfg=AveragingConfig(**base), total_steps=steps,
        backend=backend, clock=clock, callbacks=callbacks)


# ---------------------------------------------------------------------------
# Descriptor pricing: one source of truth, the old tables are gone
# ---------------------------------------------------------------------------


def test_f32_wire_bytes_match_ring_model():
    n_par, n = 123_456, 8
    assert all_mean_op().wire_bytes(n_par, n) == pytest.approx(
        ring_allreduce_bytes(n_par, n))
    # group ops price the group, and collective-free / 1-node ops are free
    g = inner_mean_op(2)
    assert g.group == 2
    assert g.wire_bytes(n_par, 2) == pytest.approx(
        ring_allreduce_bytes(n_par, 2))
    assert CollectiveOp("x", None).wire_bytes(n_par, n) == 0.0
    assert all_mean_op().wire_bytes(n_par, 1) == 0.0


def test_qsgd_wire_bytes():
    n_par, n, bits, leaves = 100_000, 8, 8, 6
    # the every-step baseline keeps the paper's levels-only accounting
    step = qsgd_step_op(bits)
    assert step.wire_bytes(n_par, n, n_tensors=leaves) == pytest.approx(
        ring_allreduce_bytes(n_par, n) * bits / 32)
    # the byte-true anchor-delta exchange counts the norm side-channel
    q = quantized_all_mean_op(bits)
    assert q.wire_bytes(n_par, n, n_tensors=leaves) == pytest.approx(
        2 * (n - 1) / n * (n_par * bits / 8 + 4 * leaves))
    assert q.wire_bytes(n_par, n, n_tensors=leaves) > \
        step.wire_bytes(n_par, n, n_tensors=leaves)


def test_program_comm_table_deleted():
    """Acceptance criterion: bytes/latency are priced solely from
    CollectiveOp descriptors — no parallel tables, no per-strategy
    collective hook."""
    import repro.backends.base as backend_base
    from repro.strategies.base import CommunicationStrategy
    assert not hasattr(backend_base, "PROGRAM_COMM")
    assert not hasattr(CommunicationStrategy, "comm_collective")


def test_strategy_accounting_derives_from_sync_op():
    n_par = 1000
    for method, expect in [
        ("adpsgd", ("all_reduce", 1.0)),
        ("fullsgd", ("all_reduce", 1.0)),
        ("qsgd", ("gather_bcast", 0.25)),
        ("qsgd_periodic", ("gather_bcast", 0.25)),
        ("dasgd", ("all_reduce", 1.0)),
    ]:
        s = make_strategy(AveragingConfig(method=method), STEPS)
        coll, frac = expect
        assert s.sync_op().collective == coll, method
        assert s.comm_bytes_per_sync(n_par, REPLICAS) == pytest.approx(
            ring_allreduce_bytes(n_par, REPLICAS) * frac), method
    assert make_strategy(
        AveragingConfig(method="dasgd"), STEPS).sync_op().overlap


def test_lower_rejects_unknown_op():
    b = make_backend("vmap")
    with pytest.raises(KeyError, match="cannot lower"):
        b.lower(CollectiveOp("warp_drive", "all_reduce"))


# ---------------------------------------------------------------------------
# Byte-true QSGD: cross-backend / cross-placement bit-parity (satellite)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cell", [("mesh", "replica_ddp"),
                                  ("mesh", "replica_tp")],
                         ids=["ddp", "tp"])
def test_byte_true_exchange_bit_parity(setup8, cell):
    """Program-level bit-parity: fed the *same* (W, anchor, key), the
    byte-true exchange gathers the same int8 levels + norms on every
    backend/placement and every receiver reduces them the same way — the
    new agreed average (anchor) and the probe S_k are bit-identical to the
    vmap reference, on 1 host device and on the 8-forced-device CI
    topology alike.  Under replica_tp XLA's different fusion of the
    gathered mean can wobble single ulps (~1e-10), so that cell asserts a
    tolerance five orders of magnitude below one quantization level
    (~norm/127 ≈ 1e-4) — any true wire-format drift would trip it."""
    _, params0, _, _ = setup8
    from repro.core import averaging as avg
    rng = np.random.RandomState(0)
    W = jax.tree_util.tree_map(
        lambda x: np.asarray(np.broadcast_to(x[None], (REPLICAS,) + x.shape))
        + 0.01 * rng.randn(REPLICAS, *x.shape).astype(np.float32), params0)
    anchor = jax.device_get(avg.replica_mean(W))
    key = jax.random.PRNGKey(42)

    def run(backend):
        b = make_backend(backend) if isinstance(backend, str) \
            else make_backend(backend[0], placement=backend[1])
        b.bind(REPLICAS)
        Wn, an, s_k = b.quantized_all_mean(8)(
            b.put_params(W), b.put_replicated(anchor), key)
        return jax.device_get(Wn), jax.device_get(an), float(s_k)

    Wv, av, sv = run("vmap")
    Wm, am, sm = run(cell)
    assert sm == sv                               # bit-equal, not approx
    bitwise = cell[1] == "replica_ddp"
    for a, b in zip(jax.tree_util.tree_leaves(av),
                    jax.tree_util.tree_leaves(am)):
        if bitwise:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        else:
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=0, atol=1e-8)
    for a, b in zip(jax.tree_util.tree_leaves(Wv),
                    jax.tree_util.tree_leaves(Wm)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0, atol=1e-8)


@pytest.mark.parametrize("cell", [("mesh", "replica_ddp"),
                                  ("mesh", "replica_tp")],
                         ids=["ddp", "tp"])
def test_byte_true_qsgd_end_to_end_parity(setup8, cell):
    """Full qsgd_periodic runs agree across placements within the matrix
    tolerances (the local step's fp jitter on a real multi-device topology
    is the only source of drift — the exchange itself is bit-matched)."""
    hv = make_engine(setup8, "qsgd_periodic").run()
    hm = make_engine(setup8, "qsgd_periodic", cell).run()
    assert hm.sync_steps == hv.sync_steps
    np.testing.assert_allclose(hm.s_k, hv.s_k, rtol=1e-3, atol=1e-5)
    np.testing.assert_allclose(hm.losses, hv.losses, rtol=2e-4, atol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(hm.final_W),
                    jax.tree_util.tree_leaves(hv.final_W)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_byte_true_qsgd_wire_bytes_measured(setup8):
    """A clocked qsgd_periodic run reports, per sync, the byte-true
    payload: ~bits/32 of the f32 ring volume plus the norm side-channel
    (acceptance criterion: the measured columns carry it)."""
    _, params0, _, _ = setup8
    leaves = jax.tree_util.tree_leaves(params0)
    n_par, n_tensors = sum(x.size for x in leaves), len(leaves)
    h = make_engine(setup8, "qsgd_periodic",
                    clock=SimulatedClock("10gbps")).run()
    by = h.timing["by_program"]
    per_sync = (by["quantized_all_mean"]["bytes"]
                / by["quantized_all_mean"]["calls"])
    expect = quantized_all_mean_op(8).wire_bytes(n_par, REPLICAS,
                                                 n_tensors=n_tensors)
    assert per_sync == pytest.approx(expect)
    ring = ring_allreduce_bytes(n_par, REPLICAS)
    assert per_sync / ring < 0.26                 # ~4x below full precision
    assert per_sync > ring * 8 / 32               # ...but norms ride along


# ---------------------------------------------------------------------------
# Real DaSGD overlap: dispatch off the step path, settle at fetch
# ---------------------------------------------------------------------------


def test_overlap_records_do_not_advance_sim_clock(setup8):
    """Acceptance criterion: the delta all-reduce is dispatched without
    blocking the step path, asserted via Timeline overlap records — the
    snapshot's record never advances simulated time at dispatch; only the
    un-overlapped remainder is charged at fetch."""
    clock = SimulatedClock("10gbps")
    h = make_engine(setup8, "dasgd", clock=clock).run()
    recs = clock.timeline.records
    snaps = [r for r in recs if r.name == "mean_delta"]
    fetches = [r for r in recs if r.name == "mean_delta.fetch"]
    assert snaps and len(snaps) == len(fetches)
    assert all(r.overlap for r in snaps)
    assert all(r.comm_s > 0 for r in snaps)       # the exchange has a cost
    for snap in snaps:
        # the next on-path record starts where the snapshot started: zero
        # simulated time passed on the step path at dispatch
        after = next(r for r in recs
                     if r.t_start >= snap.t_start and not r.overlap)
        assert after.t_start == snap.t_start
    # 2 local steps (delay) hide this tiny exchange completely at 10 Gbps:
    # the fetch records show a zero-length stall
    assert all(f.t_end - f.t_start == 0.0 for f in fetches)
    # the exchange is counted exactly once in the aggregates — the fetch
    # never re-charges it (comm_s rides the dispatch record only)
    by = h.timing["by_program"]
    assert by["mean_delta.fetch"]["comm_s"] == 0.0
    assert by["mean_delta"]["comm_s"] == pytest.approx(
        sum(r.comm_s for r in snaps))
    # and sim_wall reflects the hiding: strictly less than the serial sum
    t = h.timing
    assert t["sim_wall_s"] < t["compute_s"] + t["comm_s"]


def test_overlap_remainder_charged_when_not_hidden(setup8):
    """On a link slow enough that `delay` local steps cannot hide the
    exchange, the fetch stalls for exactly the remainder (its record's
    duration) — without double-charging the wire into the aggregates."""
    clock = SimulatedClock("0.01gbps", step_compute_s=1e-4)
    make_engine(setup8, "dasgd", clock=clock).run()
    recs = clock.timeline.records
    fetches = [r for r in recs if r.name == "mean_delta.fetch"]
    snaps = [r for r in recs if r.name == "mean_delta"]
    assert fetches and all(f.t_end - f.t_start > 0 for f in fetches)
    for snap, fetch in zip(snaps, fetches):
        wait = fetch.t_end - fetch.t_start
        assert wait < snap.comm_s                 # some overlap happened
        assert fetch.t_end == pytest.approx(snap.t_end)
        assert fetch.comm_s == 0.0                # wire charged at dispatch


def test_overlap_does_not_perturb_training(setup8):
    h0 = make_engine(setup8, "dasgd").run()
    hc = make_engine(setup8, "dasgd", clock=SimulatedClock("10gbps")).run()
    np.testing.assert_array_equal(h0.losses, hc.losses)
    assert h0.sync_steps == hc.sync_steps
    assert h0.s_k == hc.s_k


def test_overlapped_sync_callback_gets_exchange_timing(setup8):
    """on_sync's contract is the exchange's record (comm_s/bytes): for an
    overlapped sync the engine hands back the mean_delta dispatch record,
    not the apply program's collective-free one."""
    from repro.runtime.engine import Callback

    class Spy(Callback):
        def __init__(self):
            self.timings = []

        def on_sync(self, engine, k, s_k, timing=None):
            self.timings.append((k, timing))

    spy = Spy()
    make_engine(setup8, "dasgd", clock=SimulatedClock("10gbps"),
                callbacks=(spy,)).run()
    overlapped = [(k, t) for k, t in spy.timings
                  if t is not None and t.overlap]
    assert overlapped                      # steady-state snapshots arrived
    for k, t in overlapped:
        assert t.name == "mean_delta"
        assert t.step == k                 # the snapshot step, not fetch
        assert t.bytes > 0 and t.comm_s > 0


def test_wire_bytes_gate_catches_vanished_program():
    """A program whose bytes silently drop to zero disappears from the
    fresh wire_bytes dict — the gate must flag that, not skip it."""
    from benchmarks.check_regression import compare

    def doc(wire):
        return {"strategies": {"qsgd_periodic": {"timed": {"10gbps": {
            "final_loss": 2.3, "sim_wall_s": 0.3, "n_syncs": 12,
            "wire_bytes": wire}}}}}

    base = doc({"all_mean": 100.0, "quantized_all_mean": 25.0})
    assert compare(base, doc({"all_mean": 100.0,
                              "quantized_all_mean": 25.0}),
                   loss_tol=.05, time_tol=.10) == []
    msgs = compare(base, doc({"all_mean": 100.0}),
                   loss_tol=.05, time_tol=.10)
    assert any("quantized_all_mean" in m and "missing" in m for m in msgs)
    msgs = compare(base, doc({"all_mean": 100.0,
                              "quantized_all_mean": 26.0}),
                   loss_tol=.05, time_tol=.10)
    assert any("wire-format drift" in m for m in msgs)


def test_inflight_op_without_clock():
    b = make_backend("vmap")
    b.bind(2)
    fn = b.mean_delta(overlap=True)
    W = {"w": np.ones((2, 3), np.float32)}
    handle = fn(W)
    assert isinstance(handle, InFlightOp) and not handle.fetched
    delta, s_k = handle.fetch()
    assert handle.fetched
    np.testing.assert_allclose(np.asarray(delta["w"]), 0.0)
    assert float(s_k) == 0.0
    # fetch is idempotent
    assert handle.fetch() is not None


def test_dasgd_mid_flight_resume_under_overlap(setup8, tmp_path):
    """Checkpoint with the snapshot dispatched but not fetched: the saved
    state carries the fetched delta + probe + snapshot step, and the
    resumed run applies the identical correction, reports the identical
    probe at the identical snapshot step — half + resumed reassemble the
    uninterrupted history exactly."""
    h_full = make_engine(setup8, "dasgd").run()

    # warmup=2, p_const=4, delay=2: snapshot at k=5, applied at k=7 —
    # stop at step 6 with the collective in flight
    half = make_engine(setup8, "dasgd")
    h_half = half.run(num_steps=6)
    assert isinstance(half.strategy._pending, InFlightOp)
    assert half.strategy._apply_at == 7
    assert half.strategy._snap_at == 5
    state = strategy_state(half.strategy)
    assert "pending_delta" in state["_arrays"]
    assert "pending_s_k" in state["_arrays"]
    path = str(tmp_path / "ovl")
    save_checkpoint(path, half.W, opt_state=half.opt_state, step=6,
                    controller_state=state)

    resumed = make_engine(setup8, "dasgd")
    W, opt_state, meta = load_checkpoint(path)
    resumed.load_state(W, opt_state, strategy_state=meta["controller"])
    assert resumed.strategy._apply_at == 7
    assert resumed.strategy._snap_at == 5
    h_res = resumed.run(start_step=6)

    np.testing.assert_allclose(h_res.losses, h_full.losses[6:], rtol=1e-6)
    # the in-flight probe is reported by the *resumed* segment, at its
    # snapshot step: the two histories partition the full one
    assert h_half.sync_steps + h_res.sync_steps == h_full.sync_steps
    np.testing.assert_allclose(h_half.s_k + h_res.s_k, h_full.s_k,
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# Sampled WallClock (ROADMAP item)
# ---------------------------------------------------------------------------


def test_wallclock_sampling_blocks_every_n(setup8):
    clock = WallClock(sample_every=4)
    assert clock.defer_loss_readback
    h = make_engine(setup8, "cpsgd", clock=clock).run()
    # blocks only on steps 0,4,8,12 — one per dispatched program there
    sampled_steps = [k for k in range(STEPS) if k % 4 == 0]
    assert clock.n_blocks < len(clock.timeline.records)
    assert clock.n_blocks >= len(sampled_steps)
    interp = [r for r in clock.timeline.records if r.interpolated]
    direct = [r for r in clock.timeline.records if not r.interpolated]
    assert interp and direct
    assert all(r.step % 4 for r in interp)
    assert all(r.step % 4 == 0 for r in direct)
    # losses were deferred but converted: plain floats, same values
    assert all(isinstance(x, float) for x in h.losses)
    h0 = make_engine(setup8, "cpsgd").run()
    np.testing.assert_array_equal(h.losses, h0.losses)
    # the timeline still accounts real time
    assert h.timing["total_s"] > 0
    assert h.timing["n_records"] == len(clock.timeline.records)


def test_wallclock_sampling_interpolates_backlog(setup8):
    """The drained backlog measured at each sample is redistributed over
    the window: total accounted time is the real elapsed time, within the
    slack of the final (never-reconciled) window."""
    clock = WallClock(sample_every=4)
    make_engine(setup8, "cpsgd", clock=clock).run()
    tl = clock.timeline
    # interpolated records were amended to carry nonzero time overall
    interp_total = sum(r.compute_s + r.comm_s
                       for r in tl.records if r.interpolated)
    assert interp_total > 0
    # aggregates stayed consistent with the per-record values
    assert tl.compute_s + tl.comm_s == pytest.approx(
        sum(r.compute_s + r.comm_s for r in tl.records))
    # and reconciliation is two-way: the jit-compile-inflated first sample
    # must not poison later windows — accounted time up to the last sample
    # stays bounded by the clock's real elapsed time (each closed window
    # is set to its real span, never to stale estimates; only the final,
    # never-closed window still holds provisional values)
    last_direct = max(i for i, r in enumerate(tl.records)
                      if not r.interpolated)
    reconciled = tl.records[:last_direct + 1]
    assert sum(r.compute_s + r.comm_s
               for r in reconciled) <= clock.now() * 1.05


def test_wallclock_default_unchanged(setup8):
    clock = WallClock()
    assert clock.sample_every == 1 and not clock.defer_loss_readback
    make_engine(setup8, "cpsgd", steps=4, clock=clock).run()
    assert clock.n_blocks == len(clock.timeline.records)
    assert not any(r.interpolated for r in clock.timeline.records)


def test_make_clock_sample_every():
    c = make_clock("real", wallclock_sample_every=8)
    assert isinstance(c, WallClock) and c.sample_every == 8
    assert make_clock("10gbps", wallclock_sample_every=8).kind == "sim"


# ---------------------------------------------------------------------------
# Forced 8-device acceptance: overlapped DaSGD + byte-true QSGD on a real
# multi-device mesh (own interpreter — device count fixes at first jax init)
# ---------------------------------------------------------------------------

_OVERLAP8_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import jax, numpy as np
assert len(jax.devices()) == 8, jax.devices()
from repro.backends.mesh import MeshBackend
from repro.configs import AveragingConfig
from repro.data.pipeline import SyntheticImages
from repro.models.cnn import cnn_loss, init_cnn
from repro.optim import get_optimizer, make_lr_schedule
from repro.runtime.clock import SimulatedClock
from repro.runtime.engine import TrainerEngine

STEPS = 14
data = SyntheticImages(n_samples=256, seed=0)
params0 = init_cnn(jax.random.PRNGKey(0), widths=(8, 16))
opt = get_optimizer("momentum")
lr_fn = make_lr_schedule("step", 0.05, STEPS, decay_steps=(8,))

def run(method, backend, clock=None):
    cfg = AveragingConfig(method=method, p_init=2, p_const=4,
                          k_sample_frac=0.25, warmup_full_sync_steps=2)
    e = TrainerEngine(loss_fn=cnn_loss, optimizer=opt, params0=params0,
                      n_replicas=8,
                      data_fn=data.batches(n_replicas=8, per_replica_batch=4),
                      lr_fn=lr_fn, avg_cfg=cfg, total_steps=STEPS,
                      backend=backend, clock=clock)
    return e.run(), e

# byte-true QSGD over a genuine 4 data x 2 model mesh.  Program-level:
# same inputs -> the exchanged payload (new anchor + probe) is bit-equal
# to the vmap reference even with the levels all-gathered across devices.
from repro.backends import make_backend
from repro.core import averaging as avg
rng = np.random.RandomState(0)
W0 = jax.tree_util.tree_map(
    lambda x: np.asarray(np.broadcast_to(x[None], (8,) + x.shape))
    + 0.01 * rng.randn(8, *x.shape).astype(np.float32), params0)
anchor = jax.device_get(avg.replica_mean(W0))
qkey = jax.random.PRNGKey(42)

def qam(b):
    b.bind(8)
    Wn, an, sk = b.quantized_all_mean(8)(
        b.put_params(W0), b.put_replicated(anchor), qkey)
    return jax.device_get(an), float(sk)

av, sv = qam(make_backend("vmap"))
for placement in ("replica_ddp", "replica_tp"):
    am, sm = qam(MeshBackend(placement=placement))
    assert sm == sv, (placement, sm, sv)
    for a, b in zip(jax.tree_util.tree_leaves(av),
                    jax.tree_util.tree_leaves(am)):
        if placement == "replica_ddp":      # tp: 1-ulp fusion wobble only
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        else:
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=0, atol=1e-8)

# end-to-end: matrix tolerances (step-program fp jitter only)
hv, _ = run("qsgd_periodic", "vmap")
hm, em = run("qsgd_periodic", MeshBackend(placement="replica_tp"))
assert dict(em.backend.mesh.shape) == {"data": 4, "model": 2}
assert hm.sync_steps == hv.sync_steps
np.testing.assert_allclose(hm.s_k, hv.s_k, rtol=1e-3, atol=1e-5)
np.testing.assert_allclose(hm.losses, hv.losses, rtol=2e-4, atol=1e-5)
print("QSGD8 OK")

# overlapped DaSGD on the sharded mesh: overlap records, unperturbed run
clock = SimulatedClock("10gbps")
hd, ed = run("dasgd", MeshBackend(placement="replica_tp"), clock)
recs = clock.timeline.records
snaps = [r for r in recs if r.name == "mean_delta"]
assert snaps and all(r.overlap for r in snaps), snaps
assert [r for r in recs if r.name == "mean_delta.fetch"]
hd0, _ = run("dasgd", MeshBackend(placement="replica_tp"))
np.testing.assert_array_equal(hd.losses, hd0.losses)
hdv, _ = run("dasgd", "vmap")
assert hd.sync_steps == hdv.sync_steps
np.testing.assert_allclose(hd.losses, hdv.losses, rtol=2e-4, atol=1e-5)
print("OVERLAP8 OK")
"""


def test_overlap_qsgd8_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep \
        + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", _OVERLAP8_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=540)
    assert r.returncode == 0, (r.stdout, r.stderr)
    assert "QSGD8 OK" in r.stdout and "OVERLAP8 OK" in r.stdout
