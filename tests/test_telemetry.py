"""Telemetry subsystem tests (DESIGN.md §6): clocks, timed backends, the
per-collective comm model, the wall-clock AdaComm controller, and the
bench-regression gate's comparison logic.

The invariants:

* a bound clock never perturbs training — losses/schedules are
  bit-identical to an un-clocked run (the SimulatedClock never blocks and
  the WallClock only adds block_until_ready);
* every dispatched program reports one ``(compute_s, comm_s, bytes)``
  record whose bytes match the analytic ring model for the program's
  collective (group-sized for ``inner_mean``, bits/32-scaled for
  quantized exchanges);
* the time-based AdaComm schedule is a pure function of simulated time, so
  10 vs 100 Gbps produce *diverging* period trajectories (larger periods
  when communication is expensive — the paper's behavior), straggler
  slowdowns rescale the period by 1/sqrt(s), and a checkpoint/restore
  continues the same t0-second block mid-block.
"""
import math

import jax
import numpy as np
import pytest

from repro.configs import AveragingConfig
from repro.core.comm_model import (GBPS_10, GBPS_100, LATENCY_S,
                                   ring_allreduce_bytes, comm_time)
from repro.core.controller import AdaCommTimeController
from repro.data.pipeline import SyntheticImages
from repro.models.cnn import cnn_loss, init_cnn
from repro.optim import get_optimizer, make_lr_schedule
from repro.runtime.clock import (NetworkModel, SimulatedClock, WallClock,
                                 make_clock, resolve_net)
from repro.runtime.engine import Callback, TrainerEngine
from repro.checkpoint.io import (load_checkpoint, save_checkpoint,
                                 strategy_state)

STEPS = 12
REPLICAS = 4


# ---------------------------------------------------------------------------
# comm model: per-collective latency (the hierarchical-overcharge fix)
# ---------------------------------------------------------------------------


def test_comm_time_default_unchanged():
    # legacy callers (no collective kwarg) keep the ring all-reduce pricing
    b, n, bw = 1e6, 8, GBPS_100
    assert comm_time(b, 3, n, bw) == pytest.approx(
        3 * (b / bw + LATENCY_S * 2 * (n - 1)))


def test_comm_time_per_collective_hops():
    b, n, bw = 1e6, 8, GBPS_100
    ar = comm_time(b, 1, n, bw, collective="all_reduce")
    ag = comm_time(b, 1, n, bw, collective="all_gather")
    gb = comm_time(b, 1, n, bw, collective="gather_bcast")
    assert ag < ar                      # (n-1) hops vs 2(n-1)
    assert gb == ar                     # latency NOT reduced (paper §IV)
    with pytest.raises(ValueError, match="collective"):
        comm_time(b, 1, n, bw, collective="ring_of_fire")


def test_inner_mean_charged_for_group_not_world():
    """A hierarchical inner sync prices a ring within one group: fewer
    latency hops *and* fewer bytes than the full cross-replica ring —
    the old unconditional 2(n-1) overcharged it."""
    n_par, world, group, bw = 500_000, 8, 2, GBPS_10
    inner = comm_time(ring_allreduce_bytes(n_par, group), 1, group, bw,
                      collective="inner_mean")
    cross = comm_time(ring_allreduce_bytes(n_par, world), 1, world, bw,
                      collective="all_reduce")
    assert inner < cross


def test_resolve_net():
    assert resolve_net("10gbps").bandwidth == GBPS_10
    assert resolve_net("100gbps").bandwidth == GBPS_100
    assert resolve_net("25gbps").bandwidth == pytest.approx(25e9 / 8)
    nm = NetworkModel("x", 1e9, intra_bandwidth=5e9)
    assert resolve_net(nm) is nm and nm.intra == 5e9
    with pytest.raises(ValueError):
        resolve_net("carrier-pigeon")
    assert make_clock(None) is None and make_clock("none") is None
    assert isinstance(make_clock("real"), WallClock)
    assert isinstance(make_clock("10gbps"), SimulatedClock)


# ---------------------------------------------------------------------------
# Engine integration: timed programs, timeline, callbacks
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def setup4():
    data = SyntheticImages(n_samples=128, seed=0)
    params0 = init_cnn(jax.random.PRNGKey(0), widths=(8, 16))
    opt = get_optimizer("momentum")
    lr_fn = make_lr_schedule("step", 0.05, STEPS, decay_steps=(8,))
    return data, params0, opt, lr_fn


def make_engine(setup4, method="adpsgd", clock=None, callbacks=(), **cfg_kw):
    data, params0, opt, lr_fn = setup4
    base = dict(method=method, p_init=2, p_const=4, k_sample_frac=0.25,
                warmup_full_sync_steps=2, inner_period=2, adacomm_interval=4)
    base.update(cfg_kw)
    return TrainerEngine(
        loss_fn=cnn_loss, optimizer=opt, params0=params0,
        n_replicas=REPLICAS,
        data_fn=data.batches(n_replicas=REPLICAS, per_replica_batch=4),
        lr_fn=lr_fn, avg_cfg=AveragingConfig(**base), total_steps=STEPS,
        clock=clock, callbacks=callbacks)


def test_clock_does_not_perturb_training(setup4):
    h0 = make_engine(setup4).run()
    hs = make_engine(setup4, clock=SimulatedClock("10gbps")).run()
    np.testing.assert_array_equal(h0.losses, hs.losses)
    assert h0.sync_steps == hs.sync_steps
    assert h0.timing is None and hs.timing is not None


def test_simulated_timeline_is_deterministic(setup4):
    t1 = make_engine(setup4, clock=SimulatedClock("10gbps")).run().timing
    t2 = make_engine(setup4, clock=SimulatedClock("10gbps")).run().timing
    assert t1 == t2                     # bit-reproducible on CPU CI
    assert t1["comm_s"] > 0 and t1["compute_s"] > 0
    # 10 vs 100 Gbps: same dispatches, same bytes, cheaper comm
    t100 = make_engine(setup4, clock=SimulatedClock("100gbps")).run().timing
    assert t100["bytes"] == t1["bytes"]
    assert t100["comm_s"] < t1["comm_s"]
    assert t100["compute_s"] == t1["compute_s"]


def test_program_records_and_bytes(setup4):
    _, params0, _, _ = setup4
    n_par = sum(x.size for x in jax.tree_util.tree_leaves(params0))
    clock = SimulatedClock("10gbps")
    e = make_engine(setup4, clock=clock)
    h = e.run()
    by = h.timing["by_program"]
    assert by["replica_step"]["calls"] == STEPS
    assert by["all_mean"]["calls"] == h.n_syncs
    assert by["replica_step"]["comm_s"] == 0.0       # collective-free
    assert by["replica_step"]["bytes"] == 0.0
    # sync bytes are the ring all-reduce of the per-replica param count
    per_sync = by["all_mean"]["bytes"] / by["all_mean"]["calls"]
    assert per_sync == pytest.approx(ring_allreduce_bytes(n_par, REPLICAS))
    # records carry the engine iteration they belonged to
    sync_records = [r for r in clock.timeline.records if r.name == "all_mean"]
    assert [r.step for r in sync_records] == h.sync_steps


def test_quantized_and_inner_programs_priced(setup4):
    _, params0, _, _ = setup4
    n_par = sum(x.size for x in jax.tree_util.tree_leaves(params0))
    hq = make_engine(setup4, "qsgd", clock=SimulatedClock("10gbps"),
                     qsgd_bits=8).run()
    per = hq.timing["by_program"]["qsgd_step"]
    assert per["bytes"] / per["calls"] == pytest.approx(
        ring_allreduce_bytes(n_par, REPLICAS) / 4)       # 8/32 of the volume
    hh = make_engine(setup4, "hier_adpsgd", clock=SimulatedClock("10gbps"),
                     group_size=2).run()
    inner = hh.timing["by_program"]["inner_mean"]
    # inner syncs price the ring of the *group* (2), not the world (4)
    assert inner["bytes"] / inner["calls"] == pytest.approx(
        ring_allreduce_bytes(n_par, 2))
    outer = hh.timing["by_program"]["all_mean"]
    assert inner["comm_s"] / inner["calls"] < outer["comm_s"] / outer["calls"]


def test_wall_clock_measures_and_rebases(setup4):
    clock = WallClock()
    h = make_engine(setup4, clock=clock).run()
    t = h.timing
    assert t["clock"] == "wall"
    assert t["compute_s"] > 0 and t["comm_s"] > 0
    assert len(clock.timeline.records) == t["n_records"]
    # restore re-bases the epoch: now() continues from the saved time
    w2 = WallClock()
    w2.load_state_dict({"t": 123.0})
    assert w2.now() >= 123.0


class _SyncSpy(Callback):
    def __init__(self):
        self.sync_timings = []
        self.step_timings = []

    def on_step_end(self, engine, k, metrics):
        self.step_timings.append(metrics.get("timing"))

    def on_sync(self, engine, k, s_k, timing=None):
        self.sync_timings.append(timing)


def test_callbacks_receive_timing(setup4):
    spy = _SyncSpy()
    h = make_engine(setup4, clock=SimulatedClock("10gbps"),
                    callbacks=(spy,)).run()
    assert len(spy.sync_timings) == h.n_syncs
    assert all(t is not None and t.name == "all_mean" and t.comm_s > 0
               for t in spy.sync_timings)
    assert all(t is not None and t.name == "replica_step"
               for t in spy.step_timings)
    # un-clocked runs pass None, not garbage
    spy2 = _SyncSpy()
    make_engine(setup4, callbacks=(spy2,)).run()
    assert all(t is None for t in spy2.sync_timings)


# ---------------------------------------------------------------------------
# Wall-clock AdaComm: t0-second blocks, straggler rescaling, divergence
# ---------------------------------------------------------------------------


def _drive_time_controller(net, *, steps=400, straggler=1.0,
                           nbytes=36e6, t0=0.03, tau0=16):
    """Emulate the periodic dispatch loop against a SimulatedClock: one
    step charge per iteration, one all-reduce charge per sync the
    controller schedules, loss decaying in the *iteration* index — so the
    period trajectory is a pure function of the simulated network."""
    clock = SimulatedClock(net, step_compute_s=1e-3, straggler=straggler)
    cfg = AveragingConfig(method="adacomm", p_init=tau0,
                          adacomm_mode="time", adacomm_t0=t0)
    ctrl = AdaCommTimeController(cfg, steps)
    ctrl.bind_clock(clock)
    trace = []                          # (sim time, period) per iteration
    for k in range(steps):
        clock.measure("replica_step", lambda: None, (), is_step=True)
        if ctrl.sync_now(k):
            clock.measure("all_mean", lambda: None, (), is_step=False,
                          comm_bytes=nbytes, collective="all_reduce",
                          n_nodes=4)
        ctrl.observe_loss(k, math.exp(-k / 40))
        trace.append((clock.now(), ctrl.period))
    return trace, ctrl


def _period_at(trace, t):
    p = trace[0][1]
    for tt, pp in trace:
        if tt > t:
            break
        p = pp
    return p


def test_adacomm_time_periods_diverge_with_bandwidth():
    """The paper's trend: at the same *wall-clock*, the 10 Gbps run has
    completed fewer iterations (syncs cost more), sits higher on the loss
    curve, and therefore holds a larger period than the 100 Gbps run —
    communication is scheduled less often exactly when it is expensive."""
    tr10, _ = _drive_time_controller("10gbps")
    tr100, _ = _drive_time_controller("100gbps")
    assert [p for _, p in tr10] != [p for _, p in tr100]
    probes = [0.09, 0.15, 0.24]
    p10 = [_period_at(tr10, t) for t in probes]
    p100 = [_period_at(tr100, t) for t in probes]
    assert all(a >= b for a, b in zip(p10, p100))
    assert any(a > b for a, b in zip(p10, p100))
    # both adapted away from tau0 (the trajectories are live, not stuck)
    assert p10[-1] < 16 and p100[-1] < 16


def test_adacomm_time_straggler_rescaling():
    """tau* ∝ sqrt(t_comm/(s·t_step)): a straggler slowdown s shrinks the
    loss-derived period by sqrt(s) (controller docstring).  Tested on the
    update rule directly — f == f0 isolates the straggler term."""
    cfg = AveragingConfig(method="adacomm", p_init=8, adacomm_mode="time",
                          adacomm_t0=0.01)
    for s, expect in ((1.0, 8), (4.0, 4), (16.0, 2)):
        clock = SimulatedClock("100gbps", step_compute_s=1e-3, straggler=s)
        ctrl = AdaCommTimeController(cfg, 100)
        ctrl.bind_clock(clock)
        ctrl.f0 = 1.0                   # calibration done; ratio will be 1
        ctrl._block_start = 0.0
        for _ in range(30):             # advance well past t0
            clock.measure("replica_step", lambda: None, (), is_step=True)
        ctrl.observe_loss(0, 1.0)
        assert ctrl.period == expect    # ceil(8 / sqrt(s))
    with pytest.raises(ValueError, match="straggler"):
        SimulatedClock("100gbps", straggler=0.5)


def test_adacomm_iteration_mode_unaffected_by_clock(setup4):
    """The PR-2/3 iteration-counted AdaComm stays bit-exact whether or not
    a clock is bound (parity guarantee for the existing tests/benches)."""
    h0 = make_engine(setup4, "adacomm").run()
    hc = make_engine(setup4, "adacomm",
                     clock=SimulatedClock("10gbps")).run()
    assert h0.sync_steps == hc.sync_steps
    assert h0.period_history == hc.period_history
    np.testing.assert_array_equal(h0.losses, hc.losses)


def test_adacomm_time_needs_clock(setup4):
    with pytest.raises(ValueError, match="adacomm_mode='time'"):
        make_engine(setup4, "adacomm", adacomm_mode="time")
    with pytest.raises(ValueError, match="adacomm_mode"):
        make_engine(setup4, "adacomm", adacomm_mode="sundial",
                    clock=SimulatedClock("10gbps"))


# ---------------------------------------------------------------------------
# Checkpoint/resume: the time-based schedule continues mid-block
# ---------------------------------------------------------------------------


def _time_engine(setup4, clock):
    # t0 ~3.4 iterations of simulated time, so block boundaries land at
    # non-checkpoint steps: the resumed run must continue the interrupted
    # block, not restart it
    return make_engine(setup4, "adacomm", clock=clock,
                       adacomm_mode="time", adacomm_t0=0.017, p_init=2)


def test_adacomm_time_checkpoint_resume_mid_block(setup4, tmp_path):
    full = _time_engine(setup4, SimulatedClock("10gbps"))
    h_full = full.run()
    assert h_full.period_history        # the schedule actually adapted

    half = _time_engine(setup4, SimulatedClock("10gbps"))
    half.run(num_steps=STEPS // 2)
    path = str(tmp_path / "tele")
    save_checkpoint(path, half.W, opt_state=half.opt_state,
                    step=STEPS // 2,
                    controller_state=strategy_state(half.strategy),
                    clock_state=half.clock.state_dict())

    clock2 = SimulatedClock("10gbps")
    resumed = _time_engine(setup4, clock2)
    W, opt_state, meta = load_checkpoint(path)
    assert meta["clock"]["kind"] == "sim"
    resumed.load_state(W, opt_state, strategy_state=meta["controller"],
                       clock_state=meta["clock"])
    # the clock resumed from the saved coordinates, not zero
    assert clock2.now() == pytest.approx(half.clock.now())
    h_res = resumed.run(start_step=STEPS // 2)

    tail = [s for s in h_full.sync_steps if s >= STEPS // 2]
    assert h_res.sync_steps == tail
    if tail:
        assert h_res.period_history == h_full.period_history[-len(tail):]
    np.testing.assert_allclose(h_res.losses, h_full.losses[STEPS // 2:],
                               rtol=1e-6)
    # and the resumed simulated time line ends where the full run's did
    assert clock2.now() == pytest.approx(full.clock.now(), rel=1e-9)


def test_clock_state_rides_checkpoint_io(tmp_path):
    path = str(tmp_path / "clk")
    save_checkpoint(path, {"w": np.zeros(3)},
                    clock_state={"kind": "sim", "t": 1.25, "net": "10gbps"})
    _, _, meta = load_checkpoint(path)
    assert meta["clock"] == {"kind": "sim", "t": 1.25, "net": "10gbps"}
    # and absent when not saved
    save_checkpoint(path, {"w": np.zeros(3)})
    _, _, meta = load_checkpoint(path)
    assert "clock" not in meta


# ---------------------------------------------------------------------------
# Bench-regression gate comparison logic
# ---------------------------------------------------------------------------


def _bench_doc(wall=0.5, loss=2.30, syncs=12):
    return {"strategies": {"adpsgd": {"timed": {"10gbps": {
        "sim_wall_s": wall, "final_loss": loss, "n_syncs": syncs}}}}}


def test_check_regression_compare():
    from benchmarks.check_regression import compare
    base = _bench_doc()
    assert compare(base, _bench_doc(), loss_tol=.05, time_tol=.10) == []
    # improvements never fail
    assert compare(base, _bench_doc(wall=0.4, loss=2.0),
                   loss_tol=.05, time_tol=.10) == []
    # wall-clock regression beyond tolerance fails
    msgs = compare(base, _bench_doc(wall=0.6), loss_tol=.05, time_tol=.10)
    assert any("sim_wall_s" in m for m in msgs)
    # loss regression fails
    msgs = compare(base, _bench_doc(loss=2.6), loss_tol=.05, time_tol=.10)
    assert any("final_loss" in m for m in msgs)
    # schedule drift is reported
    msgs = compare(base, _bench_doc(syncs=13), loss_tol=.05, time_tol=.10)
    assert any("n_syncs" in m for m in msgs)
    # a strategy missing from the fresh run is a coverage regression
    msgs = compare(base, {"strategies": {}}, loss_tol=.05, time_tol=.10)
    assert any("missing" in m for m in msgs)
