"""Sharding-rule validity for all architectures on an abstract production
mesh: every spec must divide the dims it shards (GSPMD's hard requirement)."""
import jax
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import get_config
from repro.launch import sharding as sh
from repro.launch import specs as sp
from repro.launch.mesh import replica_axes_for

ARCHS = ["qwen2-vl-2b", "xlstm-350m", "whisper-medium", "qwen2.5-14b",
         "olmo-1b", "glm4-9b", "mixtral-8x22b", "jamba-1.5-large-398b",
         "deepseek-v2-lite-16b", "minicpm-2b"]

# jax >= 0.4.36 constructs AbstractMesh from ((name, size), ...) pairs;
# older versions took (sizes, names) positionally.
def _abstract_mesh(sizes, names):
    try:
        return AbstractMesh(tuple(zip(names, sizes)))
    except TypeError:
        return AbstractMesh(sizes, names)


MESH_1POD = _abstract_mesh((16, 16), ("data", "model"))
MESH_2POD = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _axis_sizes(mesh):
    return dict(zip(mesh.axis_names, mesh.axis_sizes))


def check_divisibility(spec_tree, abs_tree, mesh, stacked):
    sizes = _axis_sizes(mesh)
    leaves_s = jax.tree_util.tree_leaves(
        spec_tree, is_leaf=lambda s: isinstance(s, P))
    leaves_x = jax.tree_util.tree_leaves(abs_tree)
    assert len(leaves_s) == len(leaves_x)
    for spec, x in zip(leaves_s, leaves_x):
        assert len(spec) <= x.ndim, (spec, x.shape)
        for dim, entry in zip(x.shape, spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            total = int(np.prod([sizes[a] for a in axes]))
            assert dim % total == 0, (spec, x.shape)


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("mesh", [MESH_1POD, MESH_2POD],
                         ids=["1pod", "2pod"])
def test_param_specs_divisible(arch, mesh):
    run = get_config(arch)
    cfg = run.model
    multi = "pod" in mesh.axis_names
    rep = replica_axes_for(run.parallelism.plan, multi)
    R = int(np.prod([_axis_sizes(mesh)[a] for a in rep])) if rep else 1
    W = sp.abstract_params(cfg, n_replicas=R)
    spec = sh.param_specs(cfg, W, mesh, run.parallelism,
                          replica_axes=rep, stacked=True)
    check_divisibility(spec, W, mesh, stacked=True)


@pytest.mark.parametrize("arch", ["glm4-9b", "mixtral-8x22b",
                                  "deepseek-v2-lite-16b", "xlstm-350m",
                                  "jamba-1.5-large-398b"])
def test_cache_specs_divisible(arch):
    run = get_config(arch)
    cfg = run.model
    for B, S in ((128, 1024), (1, 2048)):
        caches = sp.abstract_caches(cfg, B, S)
        spec = sh.cache_specs(cfg, caches, MESH_1POD, batch=B)
        check_divisibility(spec, caches, MESH_1POD, stacked=False)


def test_big_tensors_are_sharded_qwen():
    """The heavy matrices must actually shard over 'model' (not silently
    fall back to replication)."""
    run = get_config("qwen2.5-14b")
    W = sp.abstract_params(run.model, n_replicas=16)
    spec = sh.param_specs(run.model, W, MESH_1POD, run.parallelism,
                          replica_axes=("data",), stacked=True)
    blk = spec["blocks"][0]
    assert blk["attn"]["wq"]["w"] == P("data", None, "model")
    assert blk["attn"]["wo"]["w"] == P("data", "model", None)
    assert blk["mlp"]["w_gate"]["w"] == P("data", None, "model")
    assert blk["mlp"]["w_down"]["w"] == P("data", "model", None)
    # vocab-parallel embedding (hillclimb A1): vocab dim takes 'model'
    assert spec["embed"] == P("data", "model", None)


def test_fsdp_plan_adds_data_axis():
    run = get_config("mixtral-8x22b")
    W = sp.abstract_params(run.model, n_replicas=1)
    spec = sh.param_specs(run.model, W, MESH_1POD, run.parallelism,
                          replica_axes=(), stacked=True)
    blk = spec["blocks"][0]
    # experts: E=8 not divisible by 16 -> F dim takes 'model'; fsdp adds
    # 'data' on the largest remaining dim
    s = blk["moe"]["w_gate"]
    assert "model" in s and "data" in s
    flat = [x for x in jax.tree_util.tree_leaves(
        spec, is_leaf=lambda s_: isinstance(s_, P))]
    n_data = sum(1 for s_ in flat for e in s_ if e == "data")
    assert n_data > len(flat) // 3  # most big params are fsdp-sharded


def test_replica_axes_mapping():
    assert replica_axes_for("replica_dp", False) == ("data",)
    assert replica_axes_for("replica_dp", True) == ("pod", "data")
    assert replica_axes_for("fsdp", False) == ()
    assert replica_axes_for("fsdp", True) == ("pod",)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_batch_specs_shapes(arch):
    from repro.configs import INPUT_SHAPES
    cfg = get_config(arch).model
    batch, spec = sp.train_batch_specs(cfg, INPUT_SHAPES["train_4k"], 16)
    tok = batch["tokens"]
    assert tok.shape[0] == 16 and tok.shape[1] == 16
    total_seq = tok.shape[2] + (cfg.vision.n_patches if cfg.vision else 0)
    assert total_seq == 4096
