"""Layer-scan (compile-time optimization) must be numerically identical to
the python-loop path for every block family."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import model as M

CASES = {
    "qwen2.5-14b": dict(n_layers=4),
    "mixtral-8x22b": dict(n_layers=4),
    "deepseek-v2-lite-16b": dict(n_layers=5),
    "xlstm-350m": dict(n_layers=4, layer_pattern=("mlstm", "slstm")),
    "jamba-1.5-large-398b": dict(n_layers=4, layer_pattern=("mamba", "attn")),
    "whisper-medium": dict(n_layers=4),
}


@pytest.mark.parametrize("arch", sorted(CASES))
def test_scan_equals_loop(arch):
    cfg_loop = reduced(get_config(arch).model, **CASES[arch])
    cfg_scan = dataclasses.replace(cfg_loop, scan_layers=True)
    assert cfg_scan.scan_grouping() is not None
    params = M.init_params(jax.random.PRNGKey(0), cfg_loop)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                          cfg_loop.vocab_size)}
    if cfg_loop.encoder is not None:
        batch["frames"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(2), (2, cfg_loop.encoder.n_frames,
                                    cfg_loop.d_model))
    l1, a1 = M.forward(params, batch, cfg_loop)
    l2, a2 = M.forward(params, batch, cfg_scan)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               atol=2e-4, rtol=1e-3)
    assert set(a1) == set(a2)

    # gradients agree too (scan + remat path)
    cfg_scan_r = dataclasses.replace(cfg_scan, remat=True)
    g1 = jax.grad(lambda p: M.lm_loss(p, batch, cfg_loop)[0])(params)
    g2 = jax.grad(lambda p: M.lm_loss(p, batch, cfg_scan_r)[0])(params)
    l1f = jax.tree_util.tree_leaves(g1)
    l2f = jax.tree_util.tree_leaves(g2)
    for x, y in zip(l1f, l2f):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   atol=5e-4, rtol=5e-3)


def test_grouping_matches_design():
    assert get_config("mixtral-8x22b").model.scan_grouping() == (0, 1, 56)
    assert get_config("jamba-1.5-large-398b").model.scan_grouping() == (0, 8, 9)
    assert get_config("deepseek-v2-lite-16b").model.scan_grouping() == (1, 1, 26)
    assert get_config("xlstm-350m").model.scan_grouping() == (0, 8, 3)
    red = reduced(get_config("olmo-1b").model)
    assert red.scan_grouping() is None  # reduced configs use the loop
