"""Per-architecture smoke tests (reduced configs, deliverable (f)) + decode
parity for every state kind."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import averaging as avg
from repro.launch.steps import make_loss_fn
from repro.models import model as M
from repro.optim import get_optimizer

ARCHS = ["qwen2-vl-2b", "xlstm-350m", "whisper-medium", "qwen2.5-14b",
         "olmo-1b", "glm4-9b", "mixtral-8x22b", "jamba-1.5-large-398b",
         "deepseek-v2-lite-16b", "minicpm-2b"]

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, B, S, key=KEY):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.vision is not None:
        Pv = cfg.vision.n_patches
        batch["vision_embeds"] = 0.02 * jax.random.normal(
            key, (B, Pv, cfg.d_model))
        batch["mrope_pos"] = jnp.broadcast_to(
            jnp.arange(S + Pv, dtype=jnp.int32), (3, B, S + Pv))
    if cfg.encoder is not None:
        batch["frames"] = 0.1 * jax.random.normal(
            key, (B, cfg.encoder.n_frames, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = reduced(get_config(arch).model)
    assert cfg.n_layers == 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    params = M.init_params(KEY, cfg)
    B, S = 2, 64
    batch = make_batch(cfg, B, S)
    logits, aux = M.forward(params, batch, cfg)
    S_total = S + (cfg.vision.n_patches if cfg.vision else 0)
    assert logits.shape == (B, S_total, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_one_train_step(arch):
    run = get_config(arch)
    cfg = reduced(run.model)
    params = M.init_params(KEY, cfg)
    loss_fn = make_loss_fn(cfg)
    opt = get_optimizer(run.optimizer, momentum_coef=run.momentum)
    R = 2
    W = avg.stack_replicas(params, R)
    opt_state = jax.vmap(opt.init)(W)
    step = jax.jit(avg.make_local_step(loss_fn, opt))
    b1 = make_batch(cfg, 2, 32, key=jax.random.fold_in(KEY, 1))
    b2 = make_batch(cfg, 2, 32, key=jax.random.fold_in(KEY, 2))
    batch = jax.tree_util.tree_map(lambda a, b: jnp.stack([a, b]), b1, b2)
    W2, opt2, metrics = step(W, opt_state, batch, jnp.float32(1e-2))
    assert np.isfinite(float(metrics["loss"]))
    for x in jax.tree_util.tree_leaves(W2):
        assert bool(jnp.all(jnp.isfinite(x))), arch
    # params actually moved, and replicas diverged (different batches)
    assert float(avg.parameter_variance(W2)) > 0

    W3, _, sk = avg.sync_replicas(W2, opt2)
    assert float(avg.parameter_variance(W3)) < 1e-9
    assert float(sk) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    """Incremental cached decode == full parallel forward, for every state
    kind (KV / ring-buffer / MLA latent / mamba / mLSTM / sLSTM)."""
    cfg = reduced(get_config(arch).model)
    if cfg.moe is not None:  # avoid capacity drops (inherent train/serve gap)
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=8.0))
    # vlm: text-only continuation (no vision_embeds fed to either path;
    # M-RoPE falls back to t=h=w = position, identical in both paths)
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    B, S = 2, 24
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    extra = {}
    if cfg.encoder is not None:
        frames = 0.1 * jax.random.normal(KEY, (B, cfg.encoder.n_frames,
                                               cfg.d_model))
        batch["frames"] = frames
        from repro.models import transformer as T
        extra["encoder_out"] = T.encoder_forward(params["encoder"], frames, cfg)
    full_logits, _ = M.forward(params, batch, cfg)
    caches = M.init_caches(cfg, B, S, dtype=jnp.float32)
    step = jax.jit(lambda p, b, c: M.decode_step(p, b, c, cfg))
    for t in range(S):
        lg, caches = step(params, {"tokens": toks[:, t:t + 1], **extra}, caches)
        np.testing.assert_allclose(
            np.asarray(lg[:, 0]), np.asarray(full_logits[:, t]),
            atol=5e-4, rtol=1e-3)


def test_sliding_window_ring_buffer():
    """SWA decode with a buffer smaller than the sequence stays exact."""
    cfg = reduced(get_config("mixtral-8x22b").model, sliding_window=8,
                  layer_pattern=None, moe=None, d_ff=128)
    params = M.init_params(KEY, cfg)
    B, S = 1, 32
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full_logits, _ = M.forward(params, {"tokens": toks}, cfg)
    caches = M.init_caches(cfg, B, S, dtype=jnp.float32)
    # ring buffer is only `window` wide
    assert caches["layers"][0]["k"].shape[1] == 8
    step = jax.jit(lambda p, b, c: M.decode_step(p, b, c, cfg))
    for t in range(S):
        lg, caches = step(params, {"tokens": toks[:, t:t + 1]}, caches)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full_logits[:, t]),
                                   atol=5e-4, rtol=1e-3)


def test_mla_cache_is_latent_sized():
    cfg = reduced(get_config("deepseek-v2-lite-16b").model)
    caches = M.init_caches(cfg, 2, 64, dtype=jnp.bfloat16)
    layer = caches["layers"][0]
    assert set(layer) == {"ckv", "kpe", "pos"}
    assert layer["ckv"].shape == (2, 64, cfg.mla.kv_lora_rank)
    # latent cache is much smaller than full GQA KV would be
    full_kv = 2 * 64 * cfg.n_heads * (cfg.mla.qk_nope_head_dim
                                      + cfg.mla.qk_rope_head_dim) * 2
    latent = layer["ckv"].size + layer["kpe"].size
    assert latent * 3 < full_kv


def test_moe_aux_losses_present_and_finite():
    cfg = reduced(get_config("mixtral-8x22b").model)
    params = M.init_params(KEY, cfg)
    loss, aux = M.lm_loss(params, make_batch(cfg, 2, 64), cfg)
    assert "moe_load_balance" in aux and "moe_z_loss" in aux
    assert float(aux["moe_load_balance"]) > 0
    assert np.isfinite(float(loss))


def test_minicpm_scalings_applied():
    cfg = reduced(get_config("minicpm-2b").model)
    assert cfg.emb_scale == 12.0
    assert 0 < cfg.residual_scale < 1
    assert cfg.logit_scale == pytest.approx(256.0 / 2304)


def test_full_configs_match_assignment():
    spec = {
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 1408, 102400),
        "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
    }
    for arch, (L, D, H, KV, F, V) in spec.items():
        m = get_config(arch).model
        ff = m.moe.d_ff_expert if arch == "deepseek-v2-lite-16b" else m.d_ff
        assert (m.n_layers, m.d_model, m.n_heads, m.n_kv_heads, ff,
                m.vocab_size) == (L, D, H, KV, F, V), arch
    # MoE extras
    mx = get_config("mixtral-8x22b").model.moe
    assert (mx.n_experts, mx.top_k) == (8, 2)
    ja = get_config("jamba-1.5-large-398b").model
    assert (ja.moe.n_experts, ja.moe.top_k) == (16, 2)
    assert ja.layer_pattern.count("attn") == 1 and len(ja.layer_pattern) == 8
    ds = get_config("deepseek-v2-lite-16b").model
    assert (ds.moe.n_experts, ds.moe.top_k, ds.moe.n_shared_experts) == (64, 6, 2)
    assert ds.mla.kv_lora_rank == 512
