"""End-to-end behaviour tests for the paper's system: the full train driver,
the serve driver, and the paper's headline comparison at miniature scale."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import AveragingConfig, get_config, reduced
from repro.data.pipeline import SyntheticTokens
from repro.launch.serve import generate
from repro.launch.steps import make_loss_fn, make_serve_step
from repro.models import model as M
from repro.optim import get_optimizer, make_lr_schedule
from repro.runtime.loop import evaluate, train_periodic


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = reduced(get_config("olmo-1b").model, n_layers=2, d_model=64,
                  vocab_size=64, max_seq_len=64)
    data = SyntheticTokens(cfg.vocab_size, 32, n_samples=512, seed=0)
    params0 = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, data, params0


def _train(cfg, data, params0, method, steps=60):
    avg_cfg = AveragingConfig(method=method, p_init=2, p_const=4,
                              k_sample_frac=0.25, warmup_full_sync_steps=4)
    return train_periodic(
        loss_fn=make_loss_fn(cfg), optimizer=get_optimizer("momentum"),
        params0=params0, n_replicas=4,
        data_fn=data.batches(n_replicas=4, per_replica_batch=8),
        lr_fn=make_lr_schedule("step", 0.3, steps, decay_steps=(steps // 2,)),
        avg_cfg=avg_cfg, total_steps=steps, track_variance_every=5)


def test_lm_training_end_to_end(tiny_lm):
    cfg, data, params0 = tiny_lm
    h = _train(cfg, data, params0, "adpsgd")
    assert np.mean(h.losses[-5:]) < h.losses[0] * 0.9
    assert h.n_syncs < 60
    ev = evaluate(make_loss_fn(cfg), h.final_W, data.eval_batches(64, 128))
    assert np.isfinite(ev["ce_loss"])


def test_adpsgd_comm_reduction_vs_quality(tiny_lm):
    """The paper's headline at miniature scale: ADPSGD must cut syncs vs
    FULLSGD (communication) without a big loss penalty."""
    cfg, data, params0 = tiny_lm
    hf = _train(cfg, data, params0, "fullsgd")
    ha = _train(cfg, data, params0, "adpsgd")
    assert ha.n_syncs <= 30           # >= 2x fewer syncs than FULLSGD's 60
    lf = float(np.mean(hf.losses[-8:]))
    la = float(np.mean(ha.losses[-8:]))
    assert la < lf * 1.5 + 0.2        # close in loss


def test_serve_generates_tokens(tiny_lm):
    cfg, _, params0 = tiny_lm
    prompt = jnp.asarray(np.random.RandomState(0).randint(
        0, cfg.vocab_size, size=(2, 8)), jnp.int32)
    out = generate(cfg, params0, prompt, gen_len=8)
    assert out.shape == (2, 16)
    assert int(out.max()) < cfg.vocab_size

    # batched serve_step directly
    caches = M.init_caches(cfg, 2, 16, dtype=jnp.float32)
    serve = jax.jit(make_serve_step(cfg))
    nxt, caches = serve(params0, {"tokens": prompt[:, :1]}, caches)
    assert nxt.shape == (2,)
    assert int(caches["index"]) == 1


def test_decreasing_period_is_harmful(tiny_lm):
    """Paper §V-B: decreasing the period (Wang & Joshi) underperforms
    ADPSGD at equal-or-more communication."""
    cfg, data, params0 = tiny_lm
    steps = 60
    avg_dec = AveragingConfig(method="decreasing", decreasing_p0=15,
                              decreasing_p1=3, warmup_full_sync_steps=0)
    hd = train_periodic(
        loss_fn=make_loss_fn(cfg), optimizer=get_optimizer("momentum"),
        params0=params0, n_replicas=4,
        data_fn=data.batches(n_replicas=4, per_replica_batch=8),
        lr_fn=make_lr_schedule("step", 0.3, steps, decay_steps=(30,)),
        avg_cfg=avg_dec, total_steps=steps, track_variance_every=5)
    ha = _train(cfg, data, params0, "adpsgd", steps=steps)
    # ADPSGD achieves a no-worse weighted-average variance (Eq. 9)
    assert ha.weighted_avg_variance() <= hd.weighted_avg_variance() * 1.1


def test_hierarchical_controller_two_levels():
    from repro.core.controller import HierarchicalADPSGDController
    cfg = AveragingConfig(method="adpsgd", p_init=4, k_sample_frac=0.2)
    c = HierarchicalADPSGDController(cfg, 100, inner_period=2)
    inner = sum(c.inner_sync_now(k) for k in range(20))
    outer = sum(c.sync_now(k) for k in range(20))
    assert inner == 10 and outer == 5
