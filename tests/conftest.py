import os

# Tests must see exactly 1 device (the dry-run, and only the dry-run, forces
# 512 placeholder devices via its own XLA_FLAGS before jax init).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
