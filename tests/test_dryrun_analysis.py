"""Unit tests for the dry-run analysis pipeline's pure math: HLO collective
parsing and the scan-cost affine extrapolation (no devices needed)."""
import importlib

import pytest


@pytest.fixture(scope="module")
def dryrun():
    # importing repro.launch.dryrun sets XLA_FLAGS, but jax is already
    # initialized by conftest with 1 device — the env write is inert here.
    return importlib.import_module("repro.launch.dryrun")


HLO = """
  %ar = f32[16,1024]{1,0} all-reduce(%x), replica_groups=[16,16]<=[256], to_apply=%add
  %ag = bf16[4096,128]{1,0} all-gather(%y), replica_groups=[16,16]<=[16,16]T(1,0), dimensions={0}
  %rs = f32[64]{0} reduce-scatter(%z), replica_groups=[64,4]<=[256], dimensions={0}
  %a2a = f32[8,8]{1,0} all-to-all(%w), replica_groups=[32,8]<=[256]
  %cp = f32[100]{0} collective-permute(%v), source_target_pairs={{0,1}}
  %solo = f32[9]{0} all-reduce(%q), replica_groups=[256,1]<=[256], to_apply=%add
"""


def test_parse_collectives_factors(dryrun):
    out = dryrun.parse_collectives(HLO)
    by = out["bytes_by_type"]
    # all-reduce: 16*1024*4 bytes * 2*(15/16)
    assert by["all-reduce"] == pytest.approx(16 * 1024 * 4 * 2 * 15 / 16)
    # all-gather: bf16, (n-1)/n
    assert by["all-gather"] == pytest.approx(4096 * 128 * 2 * 15 / 16)
    # reduce-scatter: result bytes * (n-1)
    assert by["reduce-scatter"] == pytest.approx(64 * 4 * 3)
    # all-to-all over 8 participants
    assert by["all-to-all"] == pytest.approx(8 * 8 * 4 * 7 / 8)
    assert by["collective-permute"] == pytest.approx(400)
    # single-participant groups contribute nothing
    assert out["count_by_type"]["all-reduce"] == 1
    assert out["total_bytes"] == pytest.approx(sum(by.values()))


def test_affine_extrapolation(dryrun):
    a1 = {"flops_per_chip": 10.0, "hbm_bytes_per_chip": 100.0,
          "collective_bytes_per_chip": 5.0,
          "collectives": {"bytes_by_type": {"all-reduce": 5.0},
                          "count_by_type": {"all-reduce": 1}}}
    a2 = {"flops_per_chip": 16.0, "hbm_bytes_per_chip": 140.0,
          "collective_bytes_per_chip": 7.0,
          "collectives": {"bytes_by_type": {"all-reduce": 6.0,
                                            "all-gather": 1.0},
                          "count_by_type": {"all-reduce": 2}}}
    # anchors L=1,2 -> per-layer deltas 6/40/2; target L=12
    out = dryrun._affine_extrapolate(a1, a2, 1, 2, 12)
    assert out["flops_per_chip"] == pytest.approx(10 + 6 * 11)
    assert out["hbm_bytes_per_chip"] == pytest.approx(100 + 40 * 11)
    assert out["collective_bytes_per_chip"] == pytest.approx(5 + 2 * 11)
    by = out["collectives"]["bytes_by_type"]
    assert by["all-reduce"] == pytest.approx(5 + 1 * 11)
    assert by["all-gather"] == pytest.approx(0 + 1 * 11)


def test_pair_runnability_rules(dryrun):
    assert dryrun.pair_is_runnable("xlstm-350m", "long_500k")
    assert dryrun.pair_is_runnable("mixtral-8x22b", "long_500k")
    assert not dryrun.pair_is_runnable("olmo-1b", "long_500k")
    assert dryrun.pair_is_runnable("whisper-medium", "decode_32k")
    # 40 pairs = 33 runnable + 7 documented skips
    runnable = sum(dryrun.pair_is_runnable(a, s) for a in dryrun.ARCHS
                   for s in ("train_4k", "prefill_32k", "decode_32k",
                             "long_500k"))
    assert runnable == 33
