"""Strategy registry + TrainerEngine: parity with the seed loop, end-to-end
runs for every registered strategy, comm accounting, and checkpoint/resume
of strategy (controller) state."""
import jax
import numpy as np
import pytest

from repro.checkpoint.io import (load_checkpoint, restore_strategy,
                                 save_checkpoint, strategy_state)
from repro.configs import AveragingConfig
from repro.core import averaging as avg
from repro.core.comm_model import GBPS_100, method_comm
from repro.core.controller import make_controller
from repro.data.pipeline import SyntheticImages
from repro.models.cnn import cnn_loss, init_cnn
from repro.optim import get_optimizer, make_lr_schedule
from repro.runtime.engine import TrainerEngine
from repro.strategies import (available_strategies, comm_stats_for,
                              get_strategy_cls, make_strategy)

STEPS = 40
REPLICAS = 4


@pytest.fixture(scope="module")
def cnn_setup():
    data = SyntheticImages(n_samples=256, seed=0)
    params0 = init_cnn(jax.random.PRNGKey(0), widths=(8, 16))
    opt = get_optimizer("momentum")
    lr_fn = make_lr_schedule("step", 0.05, STEPS, decay_steps=(25,))
    return data, params0, opt, lr_fn


def make_engine(cnn_setup, method, steps=STEPS, strategy=None, **cfg_kw):
    data, params0, opt, lr_fn = cnn_setup
    base = dict(method=method, p_init=2, p_const=4, k_sample_frac=0.25,
                warmup_full_sync_steps=2)
    base.update(cfg_kw)
    cfg = AveragingConfig(**base)
    return TrainerEngine(
        loss_fn=cnn_loss, optimizer=opt, params0=params0,
        n_replicas=REPLICAS,
        data_fn=data.batches(n_replicas=REPLICAS, per_replica_batch=8),
        lr_fn=lr_fn, avg_cfg=cfg, total_steps=steps, strategy=strategy)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


def test_registry_has_all_methods():
    for name in ("fullsgd", "cpsgd", "adpsgd", "decreasing", "qsgd",
                 "hier_adpsgd", "qsgd_periodic", "adacomm", "dasgd"):
        assert name in available_strategies()
        assert get_strategy_cls(name).name == name


def test_unknown_strategy_raises():
    with pytest.raises(KeyError):
        make_strategy(AveragingConfig(method="nope"), 10)


# ---------------------------------------------------------------------------
# Parity: the engine reproduces the seed loop exactly
# ---------------------------------------------------------------------------


def _seed_loop(cnn_setup, avg_cfg, total_steps):
    """Verbatim re-implementation of the pre-refactor string-branched loop
    (ADPSGD path) — the engine must reproduce it bit-for-bit."""
    data, params0, optimizer, lr_fn = cnn_setup
    data_fn = data.batches(n_replicas=REPLICAS, per_replica_batch=8)
    ctrl = make_controller(avg_cfg, total_steps)
    W = avg.stack_replicas(params0, REPLICAS)
    opt_state = jax.vmap(optimizer.init)(W)
    local_step = jax.jit(avg.make_local_step(cnn_loss, optimizer))
    sync = jax.jit(lambda w, o: avg.sync_replicas(
        w, o, sync_momentum=avg_cfg.sync_momentum))
    losses, s_ks, sync_steps, periods = [], [], [], []
    for k in range(total_steps):
        lr = lr_fn(k)
        W, opt_state, metrics = local_step(W, opt_state, data_fn(k), lr)
        losses.append(float(metrics["loss"]))
        if ctrl.sync_now(k):
            W, opt_state, s_k = sync(W, opt_state)
            s_k = float(s_k)
            ctrl.observe(k, lr, s_k)
            s_ks.append(s_k)
            sync_steps.append(k)
            periods.append(ctrl.period)
    return losses, s_ks, sync_steps, periods, W


def test_engine_matches_seed_loop_adpsgd(cnn_setup):
    cfg = AveragingConfig(method="adpsgd", p_init=2, p_const=4,
                          k_sample_frac=0.25, warmup_full_sync_steps=2)
    losses, s_ks, sync_steps, periods, W = _seed_loop(cnn_setup, cfg, STEPS)
    h = make_engine(cnn_setup, "adpsgd").run()
    assert h.sync_steps == sync_steps
    assert h.period_history == periods
    np.testing.assert_allclose(h.s_k, s_ks)
    np.testing.assert_allclose(h.losses, losses)
    for a, b in zip(jax.tree_util.tree_leaves(h.final_W),
                    jax.tree_util.tree_leaves(W)):
        np.testing.assert_allclose(a, b)


# ---------------------------------------------------------------------------
# End-to-end per strategy
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["fullsgd", "cpsgd", "adpsgd",
                                    "decreasing", "qsgd", "hier_adpsgd",
                                    "qsgd_periodic", "adacomm", "dasgd"])
def test_every_strategy_trains(cnn_setup, method):
    h = make_engine(cnn_setup, method, inner_period=2).run()
    assert len(h.losses) == STEPS
    assert np.mean(h.losses[-5:]) < h.losses[0] * 0.8, method
    assert h.n_syncs > 0


def test_fullsgd_counts_every_step_as_comm(cnn_setup):
    h = make_engine(cnn_setup, "fullsgd", steps=10).run()
    assert h.n_syncs == 10
    assert h.sync_steps == []          # the averaging program never runs


def test_hier_adpsgd_inner_syncs_run(cnn_setup):
    h = make_engine(cnn_setup, "hier_adpsgd", inner_period=2,
                    group_size=2).run()
    assert len(h.inner_sync_steps) > 0
    # outer syncs subsume inner ones
    assert not set(h.inner_sync_steps) & set(h.sync_steps)
    assert h.n_syncs < STEPS


def test_qsgd_periodic_composes(cnn_setup):
    """The composed strategy syncs on the adaptive schedule but moves
    qsgd_bits/32 of the bytes per sync."""
    h = make_engine(cnn_setup, "qsgd_periodic").run()
    assert 0 < h.n_syncs < STEPS
    n_par = 1000
    full = make_strategy(AveragingConfig(method="adpsgd"), STEPS)
    comp = make_strategy(AveragingConfig(method="qsgd_periodic"), STEPS)
    assert comp.comm_bytes_per_sync(n_par, REPLICAS) == pytest.approx(
        full.comm_bytes_per_sync(n_par, REPLICAS) / 4)


def test_engine_has_no_method_branches():
    """Acceptance criterion: runtime/ is strategy-agnostic."""
    import os
    import repro.runtime as rt
    root = list(rt.__path__)[0]
    for fn in os.listdir(root):
        if fn.endswith(".py"):
            src = open(os.path.join(root, fn)).read()
            assert '== "qsgd"' not in src and '== "fullsgd"' not in src, fn


# ---------------------------------------------------------------------------
# Comm accounting parity with the legacy analytic model
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["fullsgd", "cpsgd", "adpsgd",
                                    "decreasing", "qsgd"])
def test_comm_stats_match_legacy_model(method):
    cfg = AveragingConfig(method=method)
    new = comm_stats_for(method, cfg, int(1e6), 16, 100, 20, GBPS_100)
    old = method_comm(method, int(1e6), 16, 100, 20, GBPS_100)
    assert new.bytes_per_node == pytest.approx(old.bytes_per_node)
    assert new.n_events == old.n_events
    assert new.time_s == pytest.approx(old.time_s)


# ---------------------------------------------------------------------------
# Checkpoint / resume of strategy state (satellite)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["adpsgd", "hier_adpsgd"])
def test_resume_continues_identical_schedule(cnn_setup, tmp_path, method):
    """Save mid-run, restore into a fresh strategy, and the adaptive period
    p, C2, and sync schedule must continue exactly as uninterrupted."""
    kw = dict(inner_period=2, group_size=2) if method == "hier_adpsgd" else {}
    full = make_engine(cnn_setup, method, **kw)
    h_full = full.run()

    half = make_engine(cnn_setup, method, **kw)
    half.run(num_steps=STEPS // 2)
    path = str(tmp_path / "ckpt")
    save_checkpoint(path, half.W, opt_state=half.opt_state, step=STEPS // 2,
                    controller_state=strategy_state(half.strategy))

    resumed = make_engine(cnn_setup, method, **kw)
    W, opt_state, meta = load_checkpoint(path)
    assert meta["step"] == STEPS // 2
    resumed.load_state(W, opt_state, strategy_state=meta["controller"])
    # adaptive state restored exactly
    assert resumed.strategy.controller.p == half.strategy.controller.p
    assert resumed.strategy.controller.c2 == pytest.approx(
        half.strategy.controller.c2)
    h_res = resumed.run(start_step=STEPS // 2)

    full_tail = [s for s in h_full.sync_steps if s >= STEPS // 2]
    assert h_res.sync_steps == full_tail
    n_tail = len(full_tail)
    assert h_res.period_history == h_full.period_history[-n_tail:] if n_tail \
        else h_res.period_history == []
    np.testing.assert_allclose(
        h_res.losses, h_full.losses[STEPS // 2:], rtol=1e-5)
    assert resumed.strategy.controller.p == full.strategy.controller.p
    assert resumed.strategy.controller.c2 == pytest.approx(
        full.strategy.controller.c2, rel=1e-6)


def test_controller_reads_cfg_inner_period():
    from repro.core.controller import HierarchicalADPSGDController
    cfg = AveragingConfig(method="hier_adpsgd", inner_period=4)
    c = make_controller(cfg, 100)
    assert isinstance(c, HierarchicalADPSGDController)
    assert c.inner_period == 4
    assert sum(c.inner_sync_now(k) for k in range(20)) == 5
    # explicit constructor arg still wins over the config
    assert HierarchicalADPSGDController(cfg, 100, inner_period=2).inner_period == 2


def test_weighted_avg_variance_on_resumed_history(cnn_setup):
    """Eq. 9 must weight by the lr at each sampled step even when the
    history starts mid-run (lrs[0] is step start_step, not step 0)."""
    e = make_engine(cnn_setup, "cpsgd")
    e.run(num_steps=STEPS // 2)
    res = make_engine(cnn_setup, "cpsgd")
    res.load_state(e.W, e.opt_state)
    res.callbacks.append(__import__("repro.runtime.engine",
                                    fromlist=["VarianceProbe"]).VarianceProbe(4))
    h = res.run(start_step=STEPS // 2)
    assert h.lr_start_step == STEPS // 2
    # lr decays at step 25: samples after that must be weighted by 0.005
    _, _, _, lr_fn = cnn_setup
    idx = np.array(h.variance_steps) - h.lr_start_step
    np.testing.assert_allclose(np.array(h.lrs)[idx],
                               [lr_fn(s) for s in h.variance_steps])
    assert np.isfinite(h.weighted_avg_variance())


def test_load_state_rejects_export_checkpoint(cnn_setup):
    e = make_engine(cnn_setup, "cpsgd")
    with pytest.raises(ValueError, match="export-only"):
        e.load_state(avg.replica_mean(e.W))


def test_params0less_engine_resume(cnn_setup):
    """The advertised resume path: an engine built without params0 must
    guard export checkpoints and init opt_state when the checkpoint has
    none."""
    data, params0, opt, lr_fn = cnn_setup
    donor = make_engine(cnn_setup, "cpsgd")
    cfg = AveragingConfig(method="cpsgd", p_init=2, p_const=4,
                          k_sample_frac=0.25, warmup_full_sync_steps=2)

    def fresh():
        return TrainerEngine(
            loss_fn=cnn_loss, optimizer=opt, n_replicas=REPLICAS,
            data_fn=data.batches(n_replicas=REPLICAS, per_replica_batch=8),
            lr_fn=lr_fn, avg_cfg=cfg, total_steps=STEPS)

    with pytest.raises(ValueError, match="export-only"):
        fresh().load_state(avg.replica_mean(donor.W))
    e = fresh()
    e.load_state(donor.W)          # no opt_state in the "checkpoint"
    h = e.run(num_steps=4)
    assert len(h.losses) == 4 and np.isfinite(h.losses).all()


def test_checkpointer_callback_saves_post_sync_state(cnn_setup, tmp_path):
    """Checkpointer fires at iteration end: a checkpoint written on a sync
    step must hold the synced W (zero replica variance) together with the
    post-observe strategy state, and resume identically from it."""
    from repro.runtime.engine import Checkpointer
    path = str(tmp_path / "cb_ckpt")
    # cpsgd p=4, warmup=2: k=5 is a sync step and (5+1) % 6 == 0 fires it
    e = make_engine(cnn_setup, "cpsgd")
    e.callbacks.append(Checkpointer(path, every=6))
    h_full = e.run()

    res = make_engine(cnn_setup, "cpsgd")
    W, opt_state, meta = load_checkpoint(path)
    # the last callback save (k+1 multiple of 6 <= STEPS) resumes cleanly
    res.load_state(W, opt_state, strategy_state=meta["controller"])
    h_res = res.run(start_step=meta["step"])
    np.testing.assert_allclose(h_res.losses, h_full.losses[meta["step"]:],
                               rtol=1e-5)
    assert h_res.sync_steps == [s for s in h_full.sync_steps
                                if s >= meta["step"]]
    # and a sync-step checkpoint is post-sync: re-save at step 6 to check
    e2 = make_engine(cnn_setup, "cpsgd")
    e2.callbacks.append(Checkpointer(str(tmp_path / "ck6"), every=6))
    e2.run(num_steps=6)
    W6, _, _ = load_checkpoint(str(tmp_path / "ck6"))
    assert float(avg.parameter_variance(W6)) < 1e-10


def test_conflicting_avg_cfg_and_strategy_raises(cnn_setup):
    data, params0, opt, lr_fn = cnn_setup
    s = make_strategy(AveragingConfig(method="cpsgd", p_const=4), STEPS)
    with pytest.raises(ValueError, match="conflicts"):
        TrainerEngine(
            loss_fn=cnn_loss, optimizer=opt, params0=params0, n_replicas=4,
            data_fn=data.batches(n_replicas=4, per_replica_batch=8),
            lr_fn=lr_fn, avg_cfg=AveragingConfig(method="cpsgd", p_const=9),
            total_steps=STEPS, strategy=s)


def test_resumed_history_n_syncs_is_per_segment(cnn_setup, tmp_path):
    half = make_engine(cnn_setup, "cpsgd")
    h1 = half.run(num_steps=STEPS // 2)
    path = str(tmp_path / "ck")
    save_checkpoint(path, half.W, opt_state=half.opt_state, step=STEPS // 2,
                    controller_state=strategy_state(half.strategy))
    res = make_engine(cnn_setup, "cpsgd")
    W, opt_state, meta = load_checkpoint(path)
    res.load_state(W, opt_state, strategy_state=meta["controller"])
    h2 = res.run(start_step=STEPS // 2)
    assert h2.n_syncs == len(h2.sync_steps)              # per-segment
    assert h1.n_syncs + h2.n_syncs == len(h1.sync_steps) + len(h2.sync_steps)


def test_strategy_state_name_mismatch_raises():
    s = make_strategy(AveragingConfig(method="adpsgd"), 10)
    state = strategy_state(s)
    other = make_strategy(AveragingConfig(method="cpsgd"), 10)
    with pytest.raises(ValueError):
        restore_strategy(other, state)


def test_train_periodic_shim_still_works(cnn_setup):
    from repro.runtime.loop import train_periodic
    data, params0, opt, lr_fn = cnn_setup
    cfg = AveragingConfig(method="cpsgd", p_const=4,
                          warmup_full_sync_steps=2)
    h = train_periodic(
        loss_fn=cnn_loss, optimizer=opt, params0=params0, n_replicas=4,
        data_fn=data.batches(n_replicas=4, per_replica_batch=8),
        lr_fn=lr_fn, avg_cfg=cfg, total_steps=20, track_variance_every=4)
    assert len(h.losses) == 20 and h.n_syncs > 0
