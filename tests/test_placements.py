"""Placement x strategy matrix harness (DESIGN.md §5 "Placements").

Since PR 2 a behavior cell is (strategy, backend, placement); hand-written
parity tests stopped scaling at the backend layer.  This module asserts,
for **every registered strategy**, that `mesh`+`replica_ddp` and
`mesh`+`replica_tp` reproduce the `vmap` baseline — losses, the variance
probe S_k, the sync schedule, and the comm-bytes accounting — within float
tolerance, plus the placement-specific invariants (TP sharding actually
lands on the 'model' axis, the local step's HLO carries no replica-axis
collective, checkpoints are placement-neutral, hierarchical groups align
with the pod boundary).

Like tests/test_backends.py it is device-count agnostic: under the default
suite jax sees one CPU device and the meshes degenerate; the `backends-tp`
CI job re-runs it with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
so `replica_tp` runs on a genuine 4 data x 2 model topology.  The
subprocess test forces that topology regardless of the parent's platform
(the acceptance matrix).
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.backends import make_backend
from repro.backends.mesh import PLACEMENTS, MeshBackend
from repro.checkpoint.io import (load_checkpoint, save_checkpoint,
                                 strategy_state)
from repro.configs import AveragingConfig
from repro.core import averaging as avg
from repro.core.comm_model import GBPS_100
from repro.data.pipeline import SyntheticImages
from repro.models.cnn import cnn_loss, init_cnn
from repro.optim import get_optimizer, make_lr_schedule
from repro.runtime.engine import TrainerEngine
from repro.strategies import available_strategies

STEPS = 16
REPLICAS = 8


def _abstract_mesh(sizes, names):
    try:
        return AbstractMesh(tuple(zip(names, sizes)))
    except TypeError:
        return AbstractMesh(sizes, names)


@pytest.fixture(scope="module")
def setup8():
    data = SyntheticImages(n_samples=256, seed=0)
    params0 = init_cnn(jax.random.PRNGKey(0), widths=(8, 16))
    opt = get_optimizer("momentum")
    lr_fn = make_lr_schedule("step", 0.05, STEPS, decay_steps=(10,))
    return data, params0, opt, lr_fn


def resolve(backend):
    """'vmap' or ('mesh', placement) -> an ExecutionBackend argument."""
    if isinstance(backend, tuple):
        name, placement = backend
        return make_backend(name, placement=placement)
    return backend


def make_engine(setup8, method, backend="vmap", steps=STEPS, **cfg_kw):
    data, params0, opt, lr_fn = setup8
    base = dict(method=method, p_init=2, p_const=4, k_sample_frac=0.25,
                warmup_full_sync_steps=2, inner_period=2, adacomm_interval=8)
    base.update(cfg_kw)
    return TrainerEngine(
        loss_fn=cnn_loss, optimizer=opt, params0=params0,
        n_replicas=REPLICAS,
        data_fn=data.batches(n_replicas=REPLICAS, per_replica_batch=4),
        lr_fn=lr_fn, avg_cfg=AveragingConfig(**base), total_steps=steps,
        backend=resolve(backend))


@pytest.fixture(scope="module")
def vmap_baseline(setup8):
    """One vmap run per strategy, shared by every placement cell."""
    cache = {}

    def get(method):
        if method not in cache:
            e = make_engine(setup8, method)
            cache[method] = (e.run(), e)
        return cache[method]

    return get


# ---------------------------------------------------------------------------
# Placement plumbing
# ---------------------------------------------------------------------------


def test_unknown_placement_rejected():
    with pytest.raises(ValueError, match="placement"):
        MeshBackend(placement="replica_nope")


def test_replica_tp_needs_model_axis():
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="model"):
        MeshBackend(mesh=mesh, placement="replica_tp")


def test_replica_tp_specs_use_model_axis(setup8):
    """The TP placement threads base_spec through put_params: fc/conv
    leaves name the 'model' axis in their sharding (whatever its size)."""
    _, params0, opt, _ = setup8
    b = MeshBackend(placement="replica_tp")
    b.bind(REPLICAS)
    W = b.put_params(avg.stack_replicas(params0, REPLICAS))
    specs = {k: jax.tree_util.tree_map(lambda x: x.sharding.spec, W[k])
             for k in ("fc1", "fc2")}
    assert "model" in specs["fc1"]["w"]          # column-parallel
    assert "model" in specs["fc2"]["w"]          # row-parallel
    entry = specs["fc1"]["w"][0]                 # replica axis leads
    assert entry in ("data", ("pod", "data"))
    # replica_ddp keeps inner dims unsharded
    bd = MeshBackend(placement="replica_ddp")
    bd.bind(REPLICAS)
    Wd = bd.put_params(avg.stack_replicas(params0, REPLICAS))
    assert all(s is None for s in Wd["fc1"]["w"].sharding.spec[1:])


def test_replica_tp_shards_over_8_devices(setup8):
    """Meaningful under the backends-tp CI job (8 forced devices): the
    default replica_tp mesh splits 4 data x 2 model and a TP leaf really
    lands on all 8 devices."""
    if len(jax.devices()) != 8:
        pytest.skip("needs the 8-forced-device CI topology")
    _, params0, opt, _ = setup8
    b = MeshBackend(placement="replica_tp")
    assert dict(b.mesh.shape) == {"data": 4, "model": 2}
    b.bind(REPLICAS)
    W = b.put_params(avg.stack_replicas(params0, REPLICAS))
    assert len(W["fc1"]["w"].sharding.device_set) == 8
    assert not W["fc1"]["w"].sharding.is_fully_replicated


# ---------------------------------------------------------------------------
# The matrix: every registered strategy x every placement vs vmap
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("placement", PLACEMENTS)
@pytest.mark.parametrize("method", available_strategies())
def test_matrix_parity(setup8, vmap_baseline, method, placement):
    hv, ev = vmap_baseline(method)
    em = make_engine(setup8, method, ("mesh", placement))
    hm = em.run()
    assert hm.sync_steps == hv.sync_steps, (method, placement)
    assert hm.period_history == hv.period_history
    assert hm.inner_sync_steps == hv.inner_sync_steps
    assert hm.n_syncs == hv.n_syncs
    np.testing.assert_allclose(hm.losses, hv.losses, rtol=2e-4, atol=1e-5)
    np.testing.assert_allclose(hm.s_k, hv.s_k, rtol=1e-3, atol=1e-5)
    # comm-bytes accounting is placement-independent: same events, same
    # bytes per event through the strategy's own hooks
    _, params0, _, _ = setup8
    n_par = sum(x.size for x in jax.tree_util.tree_leaves(params0))
    cv = ev.strategy.comm_stats(n_par, REPLICAS, STEPS, hv.n_syncs, GBPS_100)
    cm = em.strategy.comm_stats(n_par, REPLICAS, STEPS, hm.n_syncs, GBPS_100)
    assert cm == cv


@pytest.mark.parametrize("placement", PLACEMENTS)
def test_matrix_final_params_match(setup8, vmap_baseline, placement):
    hv, _ = vmap_baseline("adpsgd")
    hm = make_engine(setup8, "adpsgd", ("mesh", placement)).run()
    for a, b in zip(jax.tree_util.tree_leaves(hm.final_W),
                    jax.tree_util.tree_leaves(hv.final_W)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Step metrics off the step path (ROADMAP item)
# ---------------------------------------------------------------------------


def test_replica_step_hlo_has_no_collectives(setup8):
    """The local step's lowered HLO carries zero replica-axis collectives:
    scalar metrics come back per-replica and are reduced by a separate
    program, so skipping a sync skips every cross-replica round."""
    data, params0, opt, _ = setup8
    b = MeshBackend(placement="replica_ddp")
    b.bind(REPLICAS)
    W = b.put_params(avg.stack_replicas(params0, REPLICAS))
    ost = b.init_opt_state(opt, W)
    batch = data.batches(n_replicas=REPLICAS, per_replica_batch=4)(0)
    _, _, metrics = b.replica_step(cnn_loss, opt)(W, ost, batch, 0.05)
    assert np.isfinite(float(metrics["loss"]))   # reduced off the step
    b.all_mean()(W, ost)
    step_fn = next(v for k, v in b._cache.items() if k[0] == "step")
    sync_fn = next(v for k, v in b._cache.items()
                   if k[0].startswith("all_mean"))
    step_hlo = step_fn.lower(W, ost, batch, 0.05).as_text()
    assert "all_reduce" not in step_hlo and "all-reduce" not in step_hlo
    # control: the sync program is where the collective lives
    assert "all_reduce" in sync_fn.lower(W, ost).as_text()


# ---------------------------------------------------------------------------
# Hierarchical groups from the mesh pod boundary (ROADMAP multi-pod item)
# ---------------------------------------------------------------------------


def test_hier_group_size_derived_from_pod_axis():
    """On a 2-pod dry-run mesh, hier_adpsgd's unset group_size resolves to
    replicas-per-pod and the device groups tile the innermost ('data')
    axis — inner syncs never cross the pod boundary."""
    mesh = _abstract_mesh((2, 2, 2), ("pod", "data", "model"))
    b = MeshBackend(mesh=mesh, placement="replica_tp")
    b.bind(8)
    assert b.replica_axes == ("pod", "data")
    assert b.n_replica_devices == 4
    assert b.default_group_size() == 4           # 8 replicas / 2 pods
    # a 4-replica group = 2 local replicas x 2 'data' devices of one pod
    assert b._device_groups(2) == [[0, 1]]
    with pytest.raises(NotImplementedError, match="tile"):
        b._device_groups(4)                      # would span the pod axis
    # single-pod meshes have no natural boundary -> strategy heuristic
    b1 = MeshBackend(mesh=_abstract_mesh((4, 2), ("data", "model")))
    b1.bind(8)
    assert b1.default_group_size() is None


def test_hier_uses_backend_group_size(setup8, vmap_baseline):
    """group_size=0 resolves through the backend; on pod-less meshes (and
    vmap) both fall back to R//2, so schedules agree with the baseline."""
    hv, _ = vmap_baseline("hier_adpsgd")
    h0 = make_engine(setup8, "hier_adpsgd", ("mesh", "replica_tp"),
                     group_size=0).run()
    hc = make_engine(setup8, "hier_adpsgd", ("mesh", "replica_tp"),
                     group_size=REPLICAS // 2).run()
    assert h0.sync_steps == hc.sync_steps == hv.sync_steps
    assert h0.inner_sync_steps == hc.inner_sync_steps
    np.testing.assert_allclose(h0.losses, hc.losses, rtol=1e-6)


# ---------------------------------------------------------------------------
# Cross-placement checkpoint resume (placement-neutral checkpoints)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("save_bk,resume_bk", [
    ("vmap", ("mesh", "replica_tp")),
    (("mesh", "replica_tp"), "vmap"),
    (("mesh", "replica_ddp"), ("mesh", "replica_tp")),
], ids=["vmap->tp", "tp->vmap", "ddp->tp"])
def test_cross_placement_resume(setup8, vmap_baseline, tmp_path,
                                save_bk, resume_bk):
    """A checkpoint saved under one placement resumes under another and
    continues the sync schedule and loss trajectory of an uninterrupted
    run — checkpoints stay placement-neutral (host arrays, re-put through
    the restoring backend's own specs)."""
    h_full, _ = vmap_baseline("adpsgd")

    half = make_engine(setup8, "adpsgd", save_bk)
    half.run(num_steps=STEPS // 2)
    path = str(tmp_path / "xpl")
    save_checkpoint(path, half.W, opt_state=half.opt_state, step=STEPS // 2,
                    controller_state=strategy_state(half.strategy))

    resumed = make_engine(setup8, "adpsgd", resume_bk)
    W, opt_state, meta = load_checkpoint(path)
    resumed.load_state(W, opt_state, strategy_state=meta["controller"])
    h_res = resumed.run(start_step=STEPS // 2)

    tail = [s for s in h_full.sync_steps if s >= STEPS // 2]
    assert h_res.sync_steps == tail
    if tail:
        assert h_res.period_history == h_full.period_history[-len(tail):]
    np.testing.assert_allclose(h_res.losses, h_full.losses[STEPS // 2:],
                               rtol=2e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Reduced-transformer family parity (ROADMAP item): one config per model
# family under mesh+replica_tp vs the vmap baseline.  The cheapest cells —
# dense and ssm, a few seconds each from nightly timings — run in the
# per-PR tier-1 suite (ROADMAP promotion item); the heavier families stay
# behind the nightly/dispatch `placements-transformer` CI job's
# PLACEMENTS_TRANSFORMER=1 opt-in (with 8 forced host devices).
# ---------------------------------------------------------------------------

TIER1_FAMILIES = ("dense", "ssm")

TRANSFORMER_FAMILIES = [
    ("dense", "olmo-1b"),
    ("moe", "mixtral-8x22b"),
    ("ssm", "xlstm-350m"),
    ("hybrid", "jamba-1.5-large-398b"),
    ("vlm", "qwen2-vl-2b"),
    ("audio", "whisper-medium"),
]
_TF_STEPS, _TF_R, _TF_B, _TF_S = 6, 4, 2, 32


def _family_engine(arch, backend):
    import jax.numpy as jnp
    from repro.configs import get_config, reduced
    from repro.data.pipeline import SyntheticTokens
    from repro.launch.steps import make_loss_fn
    from repro.models import model as M

    run_cfg = get_config(arch)
    cfg = reduced(run_cfg.model, max_seq_len=_TF_S)
    data = SyntheticTokens(cfg.vocab_size, _TF_S, n_samples=64, seed=0)
    base_fn = data.batches(n_replicas=_TF_R, per_replica_batch=_TF_B)
    if cfg.encoder is not None:
        # audio: deterministic per-step frame embeddings (post-frontend
        # stub), identical across backends so parity is meaningful
        def data_fn(k, _base=base_fn):
            b = dict(_base(k))
            rng = np.random.RandomState(1000 + k)
            b["frames"] = jnp.asarray(0.1 * rng.randn(
                _TF_R, _TF_B, cfg.encoder.n_frames,
                cfg.d_model).astype("float32"))
            return b
    else:
        data_fn = base_fn
    if isinstance(backend, tuple):
        # the transformer TP rules need the model config for base_spec
        bk = make_backend(backend[0], placement=backend[1], model_cfg=cfg)
    else:
        bk = backend
    return TrainerEngine(
        loss_fn=make_loss_fn(cfg), optimizer=get_optimizer("momentum"),
        params0=M.init_params(jax.random.PRNGKey(0), cfg),
        n_replicas=_TF_R, data_fn=data_fn, lr_fn=lambda k: 0.01,
        avg_cfg=AveragingConfig(method="adpsgd", p_init=2,
                                warmup_full_sync_steps=2, k_sample_frac=0.5),
        total_steps=_TF_STEPS, backend=bk)


@pytest.mark.parametrize("family,arch", TRANSFORMER_FAMILIES,
                         ids=[f for f, _ in TRANSFORMER_FAMILIES])
def test_transformer_family_parity(family, arch):
    if (family not in TIER1_FAMILIES
            and not os.environ.get("PLACEMENTS_TRANSFORMER")):
        pytest.skip("nightly placements-transformer job "
                    "(set PLACEMENTS_TRANSFORMER=1 to run)")
    hv = _family_engine(arch, "vmap").run()
    hm = _family_engine(arch, ("mesh", "replica_tp")).run()
    assert hm.sync_steps == hv.sync_steps, (family, arch)
    assert hm.period_history == hv.period_history
    np.testing.assert_allclose(hm.losses, hv.losses, rtol=5e-4, atol=1e-5,
                               err_msg=f"{family}/{arch}")
    np.testing.assert_allclose(hm.s_k, hv.s_k, rtol=2e-3, atol=1e-5,
                               err_msg=f"{family}/{arch}")


# ---------------------------------------------------------------------------
# Forced 8-device (4 data x 2 model) acceptance matrix — own interpreter
# because the device count is fixed at first jax init
# ---------------------------------------------------------------------------

_MATRIX8_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import jax, numpy as np
assert len(jax.devices()) == 8, jax.devices()
from repro.backends.mesh import MeshBackend
from repro.configs import AveragingConfig
from repro.data.pipeline import SyntheticImages
from repro.models.cnn import cnn_loss, init_cnn
from repro.optim import get_optimizer, make_lr_schedule
from repro.runtime.engine import TrainerEngine
from repro.strategies import available_strategies

STEPS = 14
data = SyntheticImages(n_samples=256, seed=0)
params0 = init_cnn(jax.random.PRNGKey(0), widths=(8, 16))
opt = get_optimizer("momentum")
lr_fn = make_lr_schedule("step", 0.05, STEPS, decay_steps=(8,))

def run(backend, method):
    cfg = AveragingConfig(method=method, p_init=2, p_const=4,
                          k_sample_frac=0.25, warmup_full_sync_steps=2,
                          inner_period=2, adacomm_interval=8)
    e = TrainerEngine(loss_fn=cnn_loss, optimizer=opt, params0=params0,
                      n_replicas=8,
                      data_fn=data.batches(n_replicas=8, per_replica_batch=4),
                      lr_fn=lr_fn, avg_cfg=cfg, total_steps=STEPS,
                      backend=backend)
    return e.run(), e

for method in available_strategies():
    hv, _ = run("vmap", method)
    hm, em = run(MeshBackend(placement="replica_tp"), method)
    assert dict(em.backend.mesh.shape) == {"data": 4, "model": 2}
    assert em.backend.n_replica_devices == 4
    assert hm.sync_steps == hv.sync_steps, method
    assert hm.period_history == hv.period_history, method
    assert hm.inner_sync_steps == hv.inner_sync_steps, method
    np.testing.assert_allclose(hm.losses, hv.losses, rtol=2e-4, atol=1e-5,
                               err_msg=method)
    np.testing.assert_allclose(hm.s_k, hv.s_k, rtol=1e-3, atol=1e-5,
                               err_msg=method)
    print(method, "OK")

# TP layout is real: a column-parallel leaf spans all 8 devices
_, em = run(MeshBackend(placement="replica_tp"), "adpsgd")
leaf = em.W["fc1"]["w"]
assert "model" in leaf.sharding.spec, leaf.sharding
assert len(leaf.sharding.device_set) == 8

# 2-pod mesh: hier_adpsgd derives its group from the pod boundary and
# matches the vmap schedule (R//2 == replicas-per-pod here by design)
mesh2 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
hv, _ = run("vmap", "hier_adpsgd")
hp, ep = run(MeshBackend(mesh=mesh2, placement="replica_tp"), "hier_adpsgd")
assert ep.backend.default_group_size() == 4
assert hp.sync_steps == hv.sync_steps
assert hp.inner_sync_steps == hv.inner_sync_steps
np.testing.assert_allclose(hp.losses, hv.losses, rtol=2e-4, atol=1e-5)
print("MATRIX8 OK")
"""


def test_matrix8_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep \
        + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", _MATRIX8_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=540)
    assert r.returncode == 0, (r.stdout, r.stderr)
    assert "MATRIX8 OK" in r.stdout
