"""Hillclimb pair D (bonus): minicpm-2b x train_4k.
Its vocab (122753) is indivisible by 16 => the embedding cannot
vocab-shard and the d-sharded fallback all-reduces full (B,S,V) logits.
VARIANT=baseline|pad (vocab_pad_multiple=16 -> 122768, shardable)."""
import os, sys, dataclasses
sys.argv = [sys.argv[0]]
from repro.launch import dryrun as D
from repro.configs import get_config

variant = os.environ.get("VARIANT", "baseline")
run = get_config("minicpm-2b")
if variant == "pad":
    run = dataclasses.replace(run, model=dataclasses.replace(
        run.model, vocab_pad_multiple=16))
rec = D.run_pair("minicpm-2b", "train_4k", programs=["local_step"],
                 run_override=run)
for pn, pr in rec["programs"].items():
    r = pr["roofline"]
    print(f"{variant:9s} {pn:11s} compute={r['compute_s']:.3e} "
          f"mem={r['memory_s']:.3e} coll={r['collective_s']:.3e} "
          f"dom={r['dominant']}")
    print(f"          colls: { {k: '%.2e'%v for k,v in pr['collectives']['bytes_by_type'].items()} }")
