"""Hillclimb pair C: mixtral-8x22b x train_4k (fsdp plan, memory-bound).
VARIANT=baseline|dots|bf16 — prints roofline terms."""
import os, sys, dataclasses
sys.argv = [sys.argv[0]]
from repro.launch import dryrun as D
from repro.configs import get_config

variant = os.environ.get("VARIANT", "baseline")
run = get_config("mixtral-8x22b")
if variant == "dots":      # remat policy: keep matmul outputs (less recompute)
    run = dataclasses.replace(run, model=dataclasses.replace(
        run.model, remat_policy="dots"))
elif variant == "bf16":    # bf16 parameters (halves fsdp gather + opt traffic)
    run = dataclasses.replace(run, model=dataclasses.replace(
        run.model, param_dtype="bfloat16"))
elif variant == "sp":      # megatron sequence parallelism on residual stream
    run = dataclasses.replace(run, model=dataclasses.replace(
        run.model, act_dp_axis="data", act_seq_axis="model"))
elif variant == "sp_bf16":  # SP + bf16 params (halve fsdp gathers)
    run = dataclasses.replace(run, model=dataclasses.replace(
        run.model, act_dp_axis="data", act_seq_axis="model",
        param_dtype="bfloat16"))
rec = D.run_pair("mixtral-8x22b", "train_4k",
                 programs=["local_step"], run_override=run)
for pn, pr in rec["programs"].items():
    r = pr["roofline"]
    print(f"{variant:9s} {pn:11s} compute={r['compute_s']:.3e} "
          f"mem={r['memory_s']:.3e} coll={r['collective_s']:.3e} "
          f"dom={r['dominant']}")
    print(f"          colls: { {k: '%.2e'%v for k,v in pr['collectives']['bytes_by_type'].items()} }")
    if pr.get("memory"):
        print(f"          peak_bytes/dev={pr['memory']['peak_bytes']/1e9:.2f}GB")
