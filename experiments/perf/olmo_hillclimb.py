"""Hillclimb experiment: olmo-1b train_4k (EXPERIMENTS.md §Perf pair A).
Runs A/B variants of the sharding plan and prints the roofline terms."""
import os, sys, dataclasses, json
sys.argv = [sys.argv[0]]
from repro.launch import dryrun as D
from repro.configs import get_config

variant = os.environ.get("VARIANT", "vp")
run = get_config("olmo-1b")
if variant == "baseline":       # paper-faithful naive TP (pre-hillclimb)
    run = dataclasses.replace(run, parallelism=dataclasses.replace(
        run.parallelism, vocab_parallel_embed=False))
elif variant == "vp":           # + vocab-parallel embedding (iter 1)
    pass
elif variant == "ddp":          # + model-axis-as-DP within groups (iter 2)
    run = dataclasses.replace(run, parallelism=dataclasses.replace(
        run.parallelism, plan="replica_ddp"))
elif variant == "ddp_c":        # + explicit activation constraints (iter 3)
    run = dataclasses.replace(
        run,
        parallelism=dataclasses.replace(run.parallelism, plan="replica_ddp"),
        model=dataclasses.replace(run.model, act_dp_axis="model"))
rec = D.run_pair("olmo-1b", "train_4k", programs=["local_step", "sync_step"],
                 run_override=run)
for pn, pr in rec["programs"].items():
    r = pr["roofline"]
    print(f"{variant:9s} {pn:11s} compute={r['compute_s']:.3e} "
          f"mem={r['memory_s']:.3e} coll={r['collective_s']:.3e} "
          f"dom={r['dominant']}")
    print(f"          colls: { {k: '%.2e'%v for k,v in pr['collectives']['bytes_by_type'].items()} }")
