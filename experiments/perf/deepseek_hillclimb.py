"""Hillclimb pair B: deepseek-v2-lite-16b x train_4k (§Perf).
VARIANT=baseline|novp|ddp  — prints roofline terms + collective census."""
import os, sys, dataclasses
sys.argv = [sys.argv[0]]
from repro.launch import dryrun as D
from repro.configs import get_config

variant = os.environ.get("VARIANT", "baseline")
run = get_config("deepseek-v2-lite-16b")
if variant == "novp":      # pre-hillclimb-A1 (d-sharded embedding)
    run = dataclasses.replace(run, parallelism=dataclasses.replace(
        run.parallelism, vocab_parallel_embed=False))
elif variant == "ddp":     # model axis as intra-group DP (A2 transplanted)
    run = dataclasses.replace(run, parallelism=dataclasses.replace(
        run.parallelism, plan="replica_ddp"))
elif variant == "sp":      # sequence parallelism inside each replica group
    run = dataclasses.replace(run, model=dataclasses.replace(
        run.model, act_seq_axis="model"))
rec = D.run_pair("deepseek-v2-lite-16b", "train_4k",
                 programs=["local_step", "sync_step"], run_override=run)
for pn, pr in rec["programs"].items():
    r = pr["roofline"]
    print(f"{variant:9s} {pn:11s} compute={r['compute_s']:.3e} "
          f"mem={r['memory_s']:.3e} coll={r['collective_s']:.3e} "
          f"dom={r['dominant']}")
    print(f"          colls: { {k: '%.2e'%v for k,v in pr['collectives']['bytes_by_type'].items()} }")
