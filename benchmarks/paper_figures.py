"""One benchmark per paper table/figure (miniaturized; see common.py).

fig1  — Var[W_k] over iterations for CPSGD p in {2,4,8} (variance decays,
        drops at LR-decay boundaries).
fig2  — ADPSGD vs CPSGD p=8: ADPSGD keeps V_t ~ flat early (smaller start,
        slower decay) and smaller weighted-average variance (Eq. 9).
fig3  — ADPSGD's averaging-period trajectory: increases across training and
        steps up after each LR decay.
table1— best test accuracy: SMALL_BATCH / ADPSGD / CPSGD / FULLSGD.
fig4c — modeled computation vs communication time per method @100/10 Gbps.
fig6  — modeled speedup vs single-node across 2..16 workers.
fig7  — QSGD comparison: bytes moved + final loss vs ADPSGD.
§V-B  — decreasing-period baseline is harmful (Wang & Joshi rebuttal).
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks import common as C
from repro.core.comm_model import GBPS_10, GBPS_100

Rows = List[str]


def fig1_variance_curves() -> Rows:
    rows = []
    for p in (2, 4, 8):
        t0 = time.time()
        h = C.run_method("cpsgd", p_const=p)
        us = (time.time() - t0) * 1e6 / C.TOTAL_STEPS
        v = np.array(h.variances)
        s = np.array(h.variance_steps)
        early = v[(s >= 8) & (s < C.DECAYS[0])].mean()
        late = v[s >= C.DECAYS[1]].mean()
        rows.append(C.csv_row(
            f"fig1_cpsgd_p{p}", us,
            f"early_var={early:.3e};late_var={late:.3e};"
            f"decays={early > late}"))
    return rows


def fig2_adpsgd_variance() -> Rows:
    t0 = time.time()
    ha = C.run_method("adpsgd")
    us = (time.time() - t0) * 1e6 / C.TOTAL_STEPS
    hc = C.run_method("cpsgd", p_const=8)
    wa, wc = ha.weighted_avg_variance(), hc.weighted_avg_variance()
    return [C.csv_row(
        "fig2_weighted_avg_var", us,
        f"adpsgd={wa:.3e};cpsgd_p8={wc:.3e};adpsgd_smaller={wa < wc};"
        f"syncs_adpsgd={ha.n_syncs};syncs_cpsgd={hc.n_syncs}")]


def fig3_period_trajectory() -> Rows:
    h = C.run_method("adpsgd")
    ps = h.period_history
    first, last = ps[0], ps[-1]
    increased = last >= first
    return [C.csv_row(
        "fig3_period", 0.0,
        f"p_first={first};p_last={last};increases={increased};"
        f"trajectory={'/'.join(map(str, ps[::max(1, len(ps) // 8)]))};"
        f"mean_period={C.TOTAL_STEPS / max(1, h.n_syncs):.2f}")]


def table1_accuracy() -> Rows:
    rows = []
    accs: Dict[str, float] = {}
    for name, kw in [
        ("small_batch", dict(method="fullsgd", n_replicas=1)),
        ("adpsgd", dict(method="adpsgd")),
        ("cpsgd_p8", dict(method="cpsgd", p_const=8)),
        ("fullsgd", dict(method="fullsgd")),
    ]:
        t0 = time.time()
        h = C.run_method(**kw)
        acc = C.eval_accuracy(h)
        accs[name] = acc
        rows.append(C.csv_row(
            f"table1_{name}", (time.time() - t0) * 1e6 / C.TOTAL_STEPS,
            f"accuracy={acc:.4f};final_loss={np.mean(h.losses[-8:]):.4f};"
            f"syncs={h.n_syncs}"))
    rows.append(C.csv_row(
        "table1_ordering", 0.0,
        f"adpsgd_beats_cpsgd={accs['adpsgd'] >= accs['cpsgd_p8']}"))
    return rows


def fig4c_execution_time() -> Rows:
    rows = []
    n = C.N_REPLICAS
    steps = C.TOTAL_STEPS
    ha = C.run_method("adpsgd")
    step_s = ha.wall_s / steps          # measured compute per step
    for bw, tag in ((GBPS_100, "100gbps"), (GBPS_10, "10gbps")):
        for m, syncs in [("fullsgd", steps), ("qsgd", steps),
                         ("cpsgd", steps // 8), ("adpsgd", ha.n_syncs)]:
            cm = C.comm_for(m, n, steps, syncs, bw)
            rows.append(C.csv_row(
                f"fig4c_{m}_{tag}", step_s * 1e6,
                f"comm_s={cm.time_s:.4e};comp_s={step_s * steps:.3e};"
                f"comm_bytes={cm.bytes_per_node * cm.n_events:.3e}"))
    return rows


def fig6_speedups() -> Rows:
    rows = []
    steps = C.TOTAL_STEPS
    ha = C.run_method("adpsgd")
    step_s = max(ha.wall_s / steps / C.N_REPLICAS, 1e-4)  # per-worker compute
    for nodes in (2, 4, 8, 16):
        for bw, tag in ((GBPS_100, "100gbps"), (GBPS_10, "10gbps")):
            # time vs single node: single = steps*step_s*nodes (serial work)
            full = C.comm_for("fullsgd", nodes, steps, steps, bw)
            adp = C.comm_for("adpsgd", nodes, steps,
                             max(1, ha.n_syncs), bw)
            t1 = steps * step_s * nodes
            sp_full = t1 / (steps * step_s + full.time_s)
            sp_adp = t1 / (steps * step_s + adp.time_s)
            rows.append(C.csv_row(
                f"fig6_n{nodes}_{tag}", 0.0,
                f"speedup_fullsgd={sp_full:.2f};speedup_adpsgd={sp_adp:.2f};"
                f"adpsgd_closer_to_linear={sp_adp >= sp_full}"))
    return rows


def fig7_qsgd_comparison() -> Rows:
    hq = C.run_method("qsgd")
    ha = C.run_method("adpsgd")
    bq = C.comm_for("qsgd", C.N_REPLICAS, C.TOTAL_STEPS,
                    C.TOTAL_STEPS, GBPS_100)
    ba = C.comm_for("adpsgd", C.N_REPLICAS, C.TOTAL_STEPS,
                    ha.n_syncs, GBPS_100)
    tot_q = bq.bytes_per_node * bq.n_events
    tot_a = ba.bytes_per_node * ba.n_events
    return [C.csv_row(
        "fig7_qsgd_vs_adpsgd", 0.0,
        f"qsgd_bytes={tot_q:.3e};adpsgd_bytes={tot_a:.3e};"
        f"adpsgd_half_comm={tot_a <= 0.75 * tot_q};"
        f"loss_qsgd={np.mean(hq.losses[-8:]):.4f};"
        f"loss_adpsgd={np.mean(ha.losses[-8:]):.4f}")]


def sec5b_decreasing_period() -> Rows:
    hd = C.run_method("decreasing", decreasing=(16, 4))
    ha = C.run_method("adpsgd")
    wd, wa = hd.weighted_avg_variance(), ha.weighted_avg_variance()
    return [C.csv_row(
        "sec5b_decreasing", 0.0,
        f"wavgvar_decreasing={wd:.3e};wavgvar_adpsgd={wa:.3e};"
        f"adpsgd_better={wa <= wd};"
        f"loss_decreasing={np.mean(hd.losses[-8:]):.4f};"
        f"loss_adpsgd={np.mean(ha.losses[-8:]):.4f}")]


ALL = [fig1_variance_curves, fig2_adpsgd_variance, fig3_period_trajectory,
       table1_accuracy, fig4c_execution_time, fig6_speedups,
       fig7_qsgd_comparison, sec5b_decreasing_period]
