"""Benchmark orchestrator — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig1,...]

Prints ``name,us_per_call,derived`` CSV rows (plus the roofline table if
dry-run artifacts exist under experiments/dryrun/).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--skip-roofline", action="store_true")
    ap.add_argument("--engine-json", default=None, metavar="PATH",
                    help="also write the per-strategy engine baseline "
                         "(steps/s, syncs, comm bytes) to PATH")
    args = ap.parse_args()

    from benchmarks import engine_baseline, kernel_bench, paper_figures

    jobs = [(fn.__name__, fn) for fn in paper_figures.ALL]
    jobs.append(("engine_baseline", engine_baseline.rows))
    jobs.append(("kernel_bench", kernel_bench.bench))
    if args.only:
        keep = args.only.split(",")
        jobs = [(n, f) for n, f in jobs if any(k in n for k in keep)]

    print("name,us_per_call,derived")
    t_start = time.time()
    failed = 0
    for name, fn in jobs:
        try:
            t0 = time.time()
            for row in fn():
                print(row, flush=True)
            print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
        except Exception:  # noqa: BLE001
            failed += 1
            print(f"# {name} FAILED:", file=sys.stderr)
            traceback.print_exc()

    if not args.skip_roofline:
        try:
            from benchmarks import roofline
            rows = roofline.table()
            if rows:
                print("# --- roofline (from dry-run artifacts) ---")
                for row in rows:
                    print(row)
        except Exception:  # noqa: BLE001
            traceback.print_exc()
    if args.engine_json:
        try:
            engine_baseline.write_json(args.engine_json)
            print(f"# engine baseline -> {args.engine_json}")
        except Exception:  # noqa: BLE001
            failed += 1
            traceback.print_exc()
    print(f"# total {time.time() - t_start:.1f}s, {failed} failures")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
