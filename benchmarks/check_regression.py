"""CI bench-regression gate: compare a freshly measured engine baseline
against the committed ``BENCH_engine.json``.

    PYTHONPATH=src python benchmarks/engine_baseline.py \
        --net 10gbps --net 100gbps --out BENCH_fresh.json
    PYTHONPATH=src python benchmarks/check_regression.py \
        BENCH_engine.json BENCH_fresh.json

Per strategy x simulated network the gate checks the ``timed`` columns —
the ones that are deterministic under a ``SimulatedClock`` and therefore
meaningful to gate on a shared CI runner (host wall-clock columns are
machine-dependent and only reported, never gated):

* ``final_loss``  — the run converges no worse (within ``--loss-tol``,
  relative; a loss that *improves* never fails).
* ``sim_wall_s``  — the simulated wall-clock regresses by no more than
  ``--time-tol`` (relative).  A schedule change that syncs more often, a
  program dispatched extra times, or bytes growing all surface here.
* ``n_syncs``     — the sync schedule itself is deterministic; any drift
  is reported (gated with the time tolerance via sim_wall_s anyway, but a
  count change is the clearest diagnostic).
* ``wire_bytes``  — per-program modeled wire bytes per invocation, derived
  from the ``CollectiveOp`` descriptors (``backends/ops.py``) and therefore
  exactly deterministic: any mismatch means the wire format of an exchange
  changed (e.g. a quantized path silently moving f32 again) and is gated
  with **zero** tolerance.

Column-set drift is handled asymmetrically: *added* columns in either file
are tolerated (new metrics land without invalidating the committed
baseline — the gate compares only the columns both files carry), while a
gated column that the baseline has and the fresh run lost is reported as a
coverage regression.  Strategies present only in the fresh file are fine
(new code); strategies *missing* from the fresh file fail.  Exit code 0 =
pass, 1 = regression (CI fails the job and uploads the fresh JSON as an
artifact for inspection).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List


def _timed(doc: Dict) -> Dict[str, Dict[str, Dict]]:
    return {name: row.get("timed", {})
            for name, row in doc.get("strategies", {}).items()}


def compare(base: Dict, fresh: Dict, *, loss_tol: float,
            time_tol: float) -> List[str]:
    """Return the list of regression messages (empty = pass)."""
    problems: List[str] = []
    tb, tf = _timed(base), _timed(fresh)
    for name, nets in sorted(tb.items()):
        if not nets:
            continue
        if name not in tf or not tf[name]:
            problems.append(f"{name}: missing from fresh baseline")
            continue
        for net, cols in sorted(nets.items()):
            got = tf[name].get(net)
            if got is None:
                problems.append(f"{name}/{net}: missing from fresh baseline")
                continue
            # compare only the columns both files carry: added columns on
            # either side are new metrics, not regressions — but a *gated*
            # column the fresh run lost is a coverage regression
            for col in ("final_loss", "sim_wall_s", "n_syncs", "wire_bytes"):
                if col in cols and col not in got:
                    problems.append(
                        f"{name}/{net}: gated column '{col}' missing from "
                        "fresh baseline (coverage regression)")
            if "final_loss" in cols and "final_loss" in got:
                lb, lf = cols["final_loss"], got["final_loss"]
                if lf > lb * (1 + loss_tol):
                    problems.append(
                        f"{name}/{net}: final_loss {lf} vs baseline {lb} "
                        f"(> +{loss_tol:.0%})")
            if "sim_wall_s" in cols and "sim_wall_s" in got:
                wb, wf = cols["sim_wall_s"], got["sim_wall_s"]
                if wf > wb * (1 + time_tol):
                    problems.append(
                        f"{name}/{net}: sim_wall_s {wf} vs baseline {wb} "
                        f"(> +{time_tol:.0%})")
            if "n_syncs" in cols and "n_syncs" in got \
                    and got["n_syncs"] != cols["n_syncs"]:
                problems.append(
                    f"{name}/{net}: n_syncs {got['n_syncs']} vs baseline "
                    f"{cols['n_syncs']} (schedule drift)")
            # wire bytes derive deterministically from the op descriptors:
            # exact equality, per program — and every baseline program
            # must still appear (a program whose bytes silently drop to 0
            # vanishes from the fresh dict, which is itself the drift)
            if "wire_bytes" in cols and "wire_bytes" in got:
                for prog in sorted(cols["wire_bytes"]):
                    if prog not in got["wire_bytes"]:
                        problems.append(
                            f"{name}/{net}: wire_bytes[{prog}] missing "
                            "from fresh baseline (program stopped moving "
                            "bytes or was renamed — wire-format drift)")
                    elif got["wire_bytes"][prog] != cols["wire_bytes"][prog]:
                        problems.append(
                            f"{name}/{net}: wire_bytes[{prog}] "
                            f"{got['wire_bytes'][prog]} vs baseline "
                            f"{cols['wire_bytes'][prog]} (wire-format drift)")
    return problems


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("baseline", help="committed BENCH_engine.json")
    ap.add_argument("fresh", help="freshly measured engine baseline JSON")
    ap.add_argument("--loss-tol", type=float, default=0.05,
                    help="relative final-loss regression tolerance")
    ap.add_argument("--time-tol", type=float, default=0.10,
                    help="relative simulated-wall-clock regression tolerance")
    args = ap.parse_args()
    with open(args.baseline) as f:
        base = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)
    if not any(_timed(base).values()):
        print("check_regression: baseline has no timed columns — "
              "regenerate BENCH_engine.json with --net first", file=sys.stderr)
        return 1
    problems = compare(base, fresh, loss_tol=args.loss_tol,
                       time_tol=args.time_tol)
    for p in problems:
        print(f"REGRESSION: {p}")
    if not problems:
        n = sum(len(nets) for nets in _timed(base).values())
        print(f"bench-gate OK: {n} strategy x net cells within tolerance")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
