"""Roofline table from the dry-run artifacts (deliverable (g)).

Reads experiments/dryrun/*.json and emits, per (arch x shape x mesh x
program): the three roofline terms, the dominant bottleneck, MODEL_FLOPS =
6·N·D (6·N_active·D for MoE), and the useful-compute ratio
MODEL_FLOPS / HLO_FLOPS.  Also derives the paper's headline: the effective
collective term of ADPSGD (= sync/p̄ + local) vs FULLSGD per train pair.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import INPUT_SHAPES, get_config

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..",
                          "experiments", "dryrun")
PAPER_MEAN_PERIOD = 8.03   # paper §IV-B: ADPSGD's measured mean period


def model_flops(arch: str, shape_name: str) -> Optional[float]:
    """6·N(_active)·D for a train step (fwd+bwd); 2·N·1 per decoded token."""
    from repro.launch import specs as sp
    from repro.models.model import active_param_count, param_count
    run = get_config(arch)
    cfg = run.model
    abs_p = sp.abstract_params(cfg)
    n_total = param_count(abs_p)
    n_active = active_param_count(cfg, abs_p)
    shape = INPUT_SHAPES[shape_name]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch    # decode: one token


def load_records(mesh_filter: Optional[str] = None) -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if mesh_filter and r["mesh"] != mesh_filter:
            continue
        recs.append(r)
    return recs


def n_chips(mesh: str) -> int:
    out = 1
    for d in mesh.split("x"):
        out *= int(d)
    return out


def table(mesh_filter: str = "16x16") -> List[str]:
    rows = []
    for r in load_records(mesh_filter):
        chips = n_chips(r["mesh"])
        mf = model_flops(r["arch"], r["shape"])
        for prog, p in r["programs"].items():
            roof = p["roofline"]
            hlo_total = p["flops_per_chip"] * chips
            useful = mf / hlo_total if (mf and hlo_total) else 0.0
            rows.append(
                f"roofline,{r['arch']},{r['shape']},{r['mesh']},{prog},"
                f"compute_s={roof['compute_s']:.3e},"
                f"memory_s={roof['memory_s']:.3e},"
                f"collective_s={roof['collective_s']:.3e},"
                f"dominant={roof['dominant']},"
                f"model_flops={mf:.3e},useful_ratio={useful:.3f}")
        # effective ADPSGD vs FULLSGD collective term (train pairs)
        progs = r["programs"]
        if "local_step" in progs and "sync_step" in progs and \
                "full_step" in progs:
            loc = progs["local_step"]["roofline"]["collective_s"]
            syn = progs["sync_step"]["roofline"]["collective_s"]
            ful = progs["full_step"]["roofline"]["collective_s"]
            eff = loc + syn / PAPER_MEAN_PERIOD
            save = (ful - eff) / ful if ful else 0.0
            rows.append(
                f"adpsgd_effective,{r['arch']},{r['shape']},{r['mesh']},"
                f"local={loc:.3e},sync={syn:.3e},full={ful:.3e},"
                f"effective@p{PAPER_MEAN_PERIOD}={eff:.3e},"
                f"collective_saving={save:.1%}")
    return rows


def main():
    for row in table():
        print(row)


if __name__ == "__main__":
    main()
