"""Per-strategy engine baseline: steps/s, sync counts and modeled comm
bytes for every registered strategy on the reduced CIFAR-style config, on
every registered execution backend.

    PYTHONPATH=src python -m benchmarks.run --engine-json BENCH_engine.json

The JSON gives later PRs a perf trajectory: a regression in dispatch
overhead or a change in a strategy's sync schedule shows up as a diff.
Top-level numbers per strategy are the vmap backend's (continuity with the
PR-1 baseline); the ``backends`` sub-table holds one column per
(backend, placement) cell — ``vmap``, ``mesh`` (replica_ddp) and
``mesh_tp`` (the replica_tp placement: one replica spans the 'model' mesh
axis).  On this container the mesh runs over however many host devices
XLA_FLAGS forces — 1 by default, so the mesh columns' delta is pure
shard_map/GSPMD dispatch overhead.
"""
from __future__ import annotations

import functools
import json
import time
from typing import Dict, List

from benchmarks import common as C
from repro.backends import available_backends
from repro.core.comm_model import GBPS_100
from repro.strategies import available_strategies

import numpy as np

STEPS = 60


@functools.lru_cache(maxsize=None)   # rows() + write_json share one result:
def baseline(steps: int = STEPS) -> Dict[str, Dict]:   # run_method is cached
    # too, so a second call would otherwise record ~0s compile+wall times
    out: Dict[str, Dict] = {}
    # one column per (backend, placement) cell: plain backends under their
    # registered name, plus the mesh backend's tensor-parallel placement
    # as 'mesh_tp' (one replica spans the 'model' mesh axis — DESIGN.md §5)
    variants = [(bk, bk, "replica_ddp") for bk in available_backends()]
    variants.append(("mesh_tp", "mesh", "replica_tp"))
    for name in available_strategies():
        per_backend: Dict[str, Dict] = {}
        h = None                      # the vmap history anchors the top level
        for col, bk, placement in variants:
            t0 = time.time()
            hb = C.run_method(name, steps=steps, inner_period=2, backend=bk,
                              placement=placement)
            wall = time.time() - t0
            per_backend[col] = {
                "steps_per_s": round(steps / max(hb.wall_s, 1e-9), 2),
                "wall_s": round(hb.wall_s, 3),
                "compile_plus_wall_s": round(wall, 3),
                "n_syncs": hb.n_syncs,
                "final_loss": round(float(np.mean(hb.losses[-8:])), 4),
            }
            if col == "vmap":
                h = hb
        cm = C.comm_for(name, C.N_REPLICAS, steps, h.n_syncs, GBPS_100)
        out[name] = {
            "steps": steps,
            "steps_per_s": per_backend["vmap"]["steps_per_s"],
            "wall_s": per_backend["vmap"]["wall_s"],
            "compile_plus_wall_s": per_backend["vmap"]["compile_plus_wall_s"],
            "n_syncs": h.n_syncs,
            "n_inner_syncs": len(h.inner_sync_steps),
            "final_loss": per_backend["vmap"]["final_loss"],
            "mean_period": round(steps / max(1, h.n_syncs), 2),
            "comm_bytes_per_node": cm.bytes_per_node * cm.n_events,
            "modeled_comm_s_100gbps": cm.time_s,
            "backends": per_backend,
        }
    return out


def rows(steps: int = STEPS) -> List[str]:
    out = []
    for name, r in baseline(steps).items():
        out.append(C.csv_row(
            f"engine_{name}", 1e6 / max(r["steps_per_s"], 1e-9),
            f"syncs={r['n_syncs']};loss={r['final_loss']};"
            f"comm_bytes={r['comm_bytes_per_node']:.3e}"))
    return out


def write_json(path: str, steps: int = STEPS) -> None:
    with open(path, "w") as f:
        json.dump({"config": {"n_replicas": C.N_REPLICAS,
                              "per_replica_batch": C.PER_REPLICA_BATCH,
                              "steps": steps, "base_lr": C.BASE_LR},
                   "strategies": baseline(steps)}, f, indent=2, sort_keys=True)
