"""Per-strategy engine baseline: steps/s, sync counts, comm bytes and
**measured (simulated-clock) wall-clock** for every registered strategy on
the reduced CIFAR-style config.

    PYTHONPATH=src python benchmarks/engine_baseline.py --net 10gbps
    PYTHONPATH=src python benchmarks/engine_baseline.py --net 100gbps
    PYTHONPATH=src python -m benchmarks.run --engine-json BENCH_engine.json

The JSON gives later PRs a perf trajectory: a regression in dispatch
overhead, a change in a strategy's sync schedule, or a simulated wall-clock
slowdown shows up as a diff (and fails CI's ``bench-gate`` job via
``benchmarks/check_regression.py``).

Two kinds of columns:

* ``backends`` — host wall-clock per (backend, placement) cell: ``vmap``,
  ``mesh`` (replica_ddp) and ``mesh_tp`` (replica_tp).  On this container
  the mesh runs over however many host devices XLA_FLAGS forces — 1 by
  default, so the mesh columns' delta is pure shard_map/GSPMD dispatch
  overhead.
* ``timed`` — per simulated network (10/100 Gbps): the run is executed
  under a ``SimulatedClock`` (runtime/clock.py) and every dispatched
  program charges compute + per-collective communication, so
  ``sim_wall_s``/``sim_comm_s`` are *measured from execution* (which
  programs actually ran, with their actual bytes) rather than the old
  offline ``modeled_comm_s`` estimate — and they are bit-reproducible on
  CPU CI.  ``speedup_vs_fullsgd`` is the paper's Fig 4c/5c/6 statistic;
  the ADPSGD speedup must be larger at 10 Gbps than at 100 Gbps.
  ``wire_bytes`` breaks the volume down per program and per invocation,
  priced from the ``CollectiveOp`` descriptors the backends lowered
  (``backends/ops.py``) — the byte-true quantized exchange shows up here
  at ~bits/32 of the f32 volume plus the per-tensor norm side-channel,
  and ``check_regression.py`` gates these columns with zero tolerance
  (any drift means a wire format changed).
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import subprocess
import sys
import time
from typing import Dict, List, Optional

if __package__ in (None, ""):
    # `python benchmarks/engine_baseline.py` puts benchmarks/ (not the repo
    # root) on sys.path; add the root so `import benchmarks` resolves
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks import common as C
from repro.backends import available_backends
from repro.core.comm_model import GBPS_100
from repro.strategies import available_strategies

import numpy as np

STEPS = 60
NETS = ("10gbps", "100gbps")


@functools.lru_cache(maxsize=None)   # rows() + write_json share one result:
def baseline(steps: int = STEPS) -> Dict[str, Dict]:   # run_method is cached
    # too, so a second call would otherwise record ~0s compile+wall times
    out: Dict[str, Dict] = {}
    # one column per (backend, placement) cell: plain backends under their
    # registered name, plus the mesh backend's tensor-parallel placement
    # as 'mesh_tp' (one replica spans the 'model' mesh axis — DESIGN.md §5)
    variants = [(bk, bk, "replica_ddp") for bk in available_backends()]
    variants.append(("mesh_tp", "mesh", "replica_tp"))
    for name in available_strategies():
        per_backend: Dict[str, Dict] = {}
        h = None                      # the vmap history anchors the top level
        for col, bk, placement in variants:
            t0 = time.time()
            hb = C.run_method(name, steps=steps, inner_period=2, backend=bk,
                              placement=placement)
            wall = time.time() - t0
            per_backend[col] = {
                "steps_per_s": round(steps / max(hb.wall_s, 1e-9), 2),
                "wall_s": round(hb.wall_s, 3),
                "compile_plus_wall_s": round(wall, 3),
                "n_syncs": hb.n_syncs,
                "final_loss": round(float(np.mean(hb.losses[-8:])), 4),
            }
            if col == "vmap":
                h = hb
        cm = C.comm_for(name, C.N_REPLICAS, steps, h.n_syncs, GBPS_100)
        out[name] = {
            "steps": steps,
            "steps_per_s": per_backend["vmap"]["steps_per_s"],
            "wall_s": per_backend["vmap"]["wall_s"],
            "compile_plus_wall_s": per_backend["vmap"]["compile_plus_wall_s"],
            "n_syncs": h.n_syncs,
            "n_inner_syncs": len(h.inner_sync_steps),
            "final_loss": per_backend["vmap"]["final_loss"],
            "mean_period": round(steps / max(1, h.n_syncs), 2),
            "comm_bytes_per_node": cm.bytes_per_node * cm.n_events,
            "backends": per_backend,
        }
    return out


@functools.lru_cache(maxsize=None)
def timed_baseline(net: str, steps: int = STEPS) -> Dict[str, Dict]:
    """One SimulatedClock run per strategy on ``net``: the measured
    (simulated) wall-clock / comm-time columns, plus the paper's
    speedup-vs-FULLSGD statistic computed from the executed runs."""
    cols: Dict[str, Dict] = {}
    for name in available_strategies():
        h = C.run_method(name, steps=steps, inner_period=2, net=net)
        t = h.timing
        # measured wire bytes per invocation, per program — derived from
        # the CollectiveOp descriptors, so exactly deterministic (gated
        # with zero tolerance by check_regression.py)
        wire = {p: round(v["bytes"] / v["calls"], 1)
                for p, v in sorted(t["by_program"].items()) if v["bytes"]}
        cols[name] = {
            "sim_wall_s": round(t["sim_wall_s"], 6),
            "sim_compute_s": round(t["compute_s"], 6),
            "sim_comm_s": round(t["comm_s"], 6),
            "comm_bytes_per_node": round(t["bytes"], 1),
            "wire_bytes": wire,
            "n_syncs": h.n_syncs,
            "final_loss": round(float(np.mean(h.losses[-8:])), 4),
        }
    full = cols.get("fullsgd", {}).get("sim_wall_s")
    for name, c in cols.items():
        c["speedup_vs_fullsgd"] = (
            round(full / c["sim_wall_s"], 4) if full else None)
    return cols


# ---------------------------------------------------------------------------
# inner_mean vs the cross-pod path on a forced 2-pod mesh (ROADMAP item)
# ---------------------------------------------------------------------------

_POD_BENCH_SCRIPT = r"""
import json, os, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import jax
from repro.backends.mesh import MeshBackend
from repro.core import averaging as avg
from repro.models.cnn import init_cnn
from repro.optim import get_optimizer

mesh = jax.make_mesh((2, 4), ("pod", "data"))
b = MeshBackend(mesh=mesh)
b.bind(8)
W = b.put_params(avg.stack_replicas(
    init_cnn(jax.random.PRNGKey(0), widths=(16, 32)), 8))
ost = b.init_opt_state(get_optimizer("sgd"), W)
g = b.default_group_size()                      # 4 = replicas per pod
inner = b.inner_mean(g)
allm = b.all_mean()

def bench(fn, n=20):
    jax.block_until_ready(fn())                 # compile
    t0 = time.monotonic()
    for _ in range(n):
        out = fn()
    jax.block_until_ready(out)
    return (time.monotonic() - t0) / n

t_inner = bench(lambda: inner(W))
t_cross = bench(lambda: allm(W, ost)[0])
print(json.dumps({"wall_inner_mean_s": t_inner, "wall_all_mean_s": t_cross,
                  "mesh": dict(mesh.shape), "group_size": g}))
"""


def pod_bench(nets=NETS) -> Optional[Dict]:
    """Benchmark the in-pod ``inner_mean`` against the cross-pod
    ``all_mean`` on a forced 8-device 2-pod dry-run, and price both under
    the per-collective simulated network model (the hierarchical strategy's
    whole premise is that the inner path is the cheap one)."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu")
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep \
        + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", _POD_BENCH_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    if r.returncode != 0:
        print(f"# pod_bench failed:\n{r.stderr}", file=sys.stderr)
        return None
    out = json.loads(r.stdout.strip().splitlines()[-1])
    # simulated charges for the same exchange (per event, per node)
    from repro.core.comm_model import comm_time, ring_allreduce_bytes
    from repro.runtime.clock import resolve_net
    n_par = C.n_params()
    for net in nets:
        nm = resolve_net(net)
        out[f"sim_inner_s_{net}"] = comm_time(
            ring_allreduce_bytes(n_par, out["group_size"]), 1,
            out["group_size"], nm.intra, collective="inner_mean",
            latency_s=nm.latency_s)
        out[f"sim_cross_s_{net}"] = comm_time(
            ring_allreduce_bytes(n_par, C.N_REPLICAS), 1, C.N_REPLICAS,
            nm.bandwidth, collective="all_reduce", latency_s=nm.latency_s)
    return out


# ---------------------------------------------------------------------------
# Output
# ---------------------------------------------------------------------------


def rows(steps: int = STEPS) -> List[str]:
    out = []
    for name, r in baseline(steps).items():
        out.append(C.csv_row(
            f"engine_{name}", 1e6 / max(r["steps_per_s"], 1e-9),
            f"syncs={r['n_syncs']};loss={r['final_loss']};"
            f"comm_bytes={r['comm_bytes_per_node']:.3e}"))
    return out


def write_json(path: str, steps: int = STEPS, nets=NETS,
               include_backends: bool = True,
               include_pod_bench: bool = True) -> None:
    """Write (or update) the engine baseline JSON.  When the file already
    exists and a table is not being regenerated, its previous values are
    kept — so ``--net``-only runs refresh the timed columns without paying
    for the 3-backend wall table and vice versa."""
    doc: Dict = {}
    if os.path.exists(path):
        with open(path) as f:
            doc = json.load(f)
    doc["config"] = {"n_replicas": C.N_REPLICAS,
                     "per_replica_batch": C.PER_REPLICA_BATCH,
                     "steps": steps, "base_lr": C.BASE_LR,
                     "sim_step_compute_s": C.SIM_STEP_COMPUTE_S}
    strategies = doc.setdefault("strategies", {})
    if include_backends:
        for name, row in baseline(steps).items():
            row = dict(row)
            prev = strategies.get(name, {})
            if "timed" in prev:
                row["timed"] = prev["timed"]
            strategies[name] = row
    for net in nets:
        for name, cols in timed_baseline(net, steps).items():
            strategies.setdefault(name, {}).setdefault(
                "timed", {})[net] = cols
    if include_pod_bench:
        pb = pod_bench(nets)
        if pb is not None:
            doc["hier_inner_vs_cross"] = pb
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--net", action="append", default=None,
                    metavar="10gbps|100gbps|<x>gbps",
                    help="simulated network(s) for the timed columns "
                         "(repeatable; default: 10gbps and 100gbps)")
    ap.add_argument("--out", default="BENCH_engine.json")
    ap.add_argument("--steps", type=int, default=STEPS)
    ap.add_argument("--full", action="store_true",
                    help="also regenerate the per-backend wall-clock table "
                         "(slow: every strategy x vmap/mesh/mesh_tp)")
    ap.add_argument("--pod-bench", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="include the forced-2-pod inner_mean vs cross-pod "
                         "all_mean rows")
    args = ap.parse_args()
    nets = tuple(args.net) if args.net else NETS
    write_json(args.out, steps=args.steps, nets=nets,
               include_backends=args.full,
               include_pod_bench=args.pod_bench)
    print(f"# engine baseline -> {args.out} (nets={','.join(nets)}"
          f"{', +backends' if args.full else ''})")


if __name__ == "__main__":
    main()
