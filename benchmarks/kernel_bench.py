"""Microbenchmarks for the Pallas kernels (interpret mode on CPU: the
numbers are a harness check, not TPU performance; on TPU the same harness
times the Mosaic-compiled kernels) + jnp-reference comparison."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(fn, *args, iters: int = 3) -> float:
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters * 1e6


def bench() -> List[str]:
    rows = []
    key = jax.random.PRNGKey(0)
    B, S, H, K, d = 1, 512, 4, 2, 64
    q = jax.random.normal(key, (B, S, H, d))
    k = jax.random.normal(key, (B, S, K, d))
    v = jax.random.normal(key, (B, S, K, d))
    us_k = _time(lambda a, b, c: ops.flash_attention(a, b, c), q, k, v)
    us_r = _time(jax.jit(lambda a, b, c: ref.attention_ref(a, b, c)), q, k, v)
    flops = 4 * B * H * S * S * d
    rows.append(f"kernel_flash_attention,{us_k:.1f},"
                f"ref_us={us_r:.1f};flops={flops:.3e};shape=b{B}s{S}h{H}d{d}")

    x = jax.random.normal(key, (1 << 20,))
    u = jax.random.uniform(key, (1 << 20,))
    us_k = _time(lambda a, b: ops.qsgd_quantize(a, b), x, u)
    us_r = _time(jax.jit(lambda a, b: ref.quantize_ref(a, b)), x, u)
    rows.append(f"kernel_qsgd_quantize,{us_k:.1f},"
                f"ref_us={us_r:.1f};bytes={x.nbytes:.3e};n=1M")

    w = jax.random.normal(key, (16, 1 << 16))
    us_k = _time(lambda a: ops.param_mean_and_sqdev(a), w)
    us_r = _time(jax.jit(lambda a: ref.mean_and_sqdev_ref(a)), w)
    rows.append(f"kernel_param_variance,{us_k:.1f},"
                f"ref_us={us_r:.1f};bytes={w.nbytes:.3e};replicas=16")

    # the decision point for VmapBackend(use_kernel=...): whole-sync wall
    # time with the fused Pallas mean+sqdev kernel vs the jnp path.  On CPU
    # (interpret mode) the kernel loses by orders of magnitude — hence the
    # backend's default of kernel-on-TPU-only; on TPU this same row shows
    # the fusion winning on bandwidth-bound buffer sizes.
    from repro.core.averaging import sync_replicas
    for logn in (14, 18):
        W = {"w": jax.random.normal(key, (8, 1 << logn))}
        us_k = _time(jax.jit(
            lambda t: sync_replicas(t, use_kernel=True)[::2]), W)
        us_r = _time(jax.jit(
            lambda t: sync_replicas(t, use_kernel=False)[::2]), W)
        rows.append(
            f"kernel_sync_replicas_n{1 << logn},{us_k:.1f},"
            f"ref_us={us_r:.1f};kernel_wins={us_k < us_r};"
            f"bytes={W['w'].nbytes:.3e};replicas=8")
    return rows
