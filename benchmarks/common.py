"""Shared benchmark harness: the paper's CIFAR-10 experiment, miniaturized
(synthetic 32x32 data, compact CNN) so every figure/table reproduces on this
container in minutes.  Settings mirror §IV-A: 16 workers -> ``N_REPLICAS``
(default 8 here), step LR decay at 1/2 and 3/4 of training, momentum 0.9."""
from __future__ import annotations

import functools
import time
import jax

from repro.configs import AveragingConfig
from repro.data.pipeline import SyntheticImages
from repro.models.cnn import cnn_loss, init_cnn
from repro.optim import get_optimizer, make_lr_schedule
from repro.runtime.engine import TrainerEngine, TrainHistory, evaluate

N_REPLICAS = 8
PER_REPLICA_BATCH = 16
TOTAL_STEPS = 120
# paper uses 0.1 on CIFAR GoogLeNet; our compact CNN on synthetic data needs
# 0.05 with momentum 0.9 to stay in the convergent regime (0.1 diverges to
# the chance plateau and every method ties — measured, see git history)
BASE_LR = 0.05
DECAYS = (TOTAL_STEPS // 2, 3 * TOTAL_STEPS // 4)
# SimulatedClock per-step compute charge for the timed baselines: 5 ms puts
# the compact CNN's ~3.7 MB ring all-reduce in the paper's comm/compute
# regime (comm is ~60% of a step at 10 Gbps, ~6% at 100 Gbps — the ratio
# GoogLeNet/ResNet see on the paper's 16-node cluster), so the measured
# speedup table reproduces the paper's Fig 4c/5c/6 *trend* on CPU CI
SIM_STEP_COMPUTE_S = 5e-3


@functools.lru_cache(maxsize=None)
def setup():
    data = SyntheticImages(n_samples=2048, seed=0)
    params0 = init_cnn(jax.random.PRNGKey(0), widths=(16, 32))
    return data, params0


@functools.lru_cache(maxsize=None)
def run_method(method: str, p_const: int = 8, p_init: int = 4,
               steps: int = TOTAL_STEPS, n_replicas: int = N_REPLICAS,
               track_every: int = 2, warmup: int = 4,
               decreasing=(20, 5), inner_period: int = 1,
               backend: str = "vmap",
               placement: str = "replica_ddp",
               net: str = "") -> TrainHistory:
    """One engine run.  ``net`` (e.g. '10gbps'/'100gbps') attaches a
    ``SimulatedClock`` so ``hist.timing`` carries measured-from-execution
    simulated wall-clock/comm columns (bit-reproducible on CPU)."""
    data, params0 = setup()
    if placement != "replica_ddp":
        # non-default placements are a mesh-backend knob (DESIGN.md §5)
        from repro.backends import make_backend
        backend = make_backend(backend, placement=placement)
    clock = None
    if net:
        from repro.runtime.clock import SimulatedClock
        clock = SimulatedClock(net, step_compute_s=SIM_STEP_COMPUTE_S)
    cfg = AveragingConfig(
        method=method, p_init=p_init, p_const=p_const, k_sample_frac=0.25,
        warmup_full_sync_steps=warmup, decreasing_p0=decreasing[0],
        decreasing_p1=decreasing[1], inner_period=inner_period)
    lr_fn = make_lr_schedule("step", BASE_LR, steps,
                             decay_steps=(steps // 2, 3 * steps // 4))
    engine = TrainerEngine(
        loss_fn=cnn_loss, optimizer=get_optimizer("momentum"),
        params0=params0, n_replicas=n_replicas,
        data_fn=data.batches(n_replicas=n_replicas,
                             per_replica_batch=PER_REPLICA_BATCH),
        lr_fn=lr_fn, avg_cfg=cfg, total_steps=steps, backend=backend,
        clock=clock, track_variance_every=track_every)
    t0 = time.time()
    hist = engine.run()
    hist.wall_s = time.time() - t0
    return hist


def eval_accuracy(hist: TrainHistory) -> float:
    data, _ = setup()
    ev = evaluate(cnn_loss, hist.final_W, data.eval_batches(256))
    return ev["accuracy"]


def n_params() -> int:
    _, params0 = setup()
    return sum(x.size for x in jax.tree_util.tree_leaves(params0))


def comm_for(method: str, n_nodes: int, steps: int, n_syncs: int,
             bandwidth: float):
    """Analytic comm cost via the strategy's own accounting hooks."""
    from repro.strategies import comm_stats_for
    return comm_stats_for(method, AveragingConfig(method=method), n_params(),
                          n_nodes, steps, n_syncs, bandwidth)


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
