"""Telemetry clocks: wall-clock and simulated time for the training loop.

The paper's headline claims are *wall-clock* claims (1.14-1.27x over
full-communication SGD at 100 Gbps, 1.46-1.95x at 10 Gbps), so time is a
first-class engine citizen.  A ``Clock`` is bound to the
``ExecutionBackend`` (``backend.set_clock``); every compiled program the
backend hands a strategy is wrapped by ``backend.timed(...)`` and reports
one ``ProgramTiming`` — ``(compute_s, comm_s, bytes)`` — per invocation
into the clock's ``Timeline`` (DESIGN.md §6).

Two implementations:

* ``WallClock``      — real ``time.monotonic()`` around dispatched,
  block-until-ready program calls.  A fused program (``full_step``) cannot
  split its measured time, so the whole measurement is attributed to the
  program's *primary* cost: compute for step programs, communication for
  sync programs; the modeled bytes ride along either way.
* ``SimulatedClock`` — never blocks and never reads the host clock.
  Compute is charged from a per-step cost (``step_compute_s``, times the
  ``straggler`` slowdown — the block waits for the slowest replica) and
  communication from ``core/comm_model.py``'s per-collective
  ``comm_time`` under a configurable ``NetworkModel`` (``10gbps`` /
  ``100gbps`` / any ``<x>gbps``).  Simulated time is a pure function of
  the dispatch sequence, so timing-dependent behavior (the wall-clock
  AdaComm controller, the bench-regression gate) is bit-reproducible on
  CPU CI.

Both clocks understand **overlap ops** (``backends/ops.py``): an
``overlap=True`` collective is handed to ``dispatch_async`` — recorded on
the Timeline with ``overlap=True`` but never blocking (WallClock) nor
advancing simulated time (SimulatedClock) — and settled when the caller
fetches the ``InFlightOp``: the WallClock blocks there and records the
observed stall as a ``<name>.fetch`` record, the SimulatedClock advances
only by the *un-overlapped remainder* ``max(0, t_end − now)``.  That is how
DaSGD's delayed correction gets honest wall-clock credit for hiding the
all-reduce behind local steps.

``WallClock(sample_every=N)`` trades per-dispatch fidelity for pipeline
depth: it blocks-until-ready only on every N-th engine step and
interpolates the unsampled records in the Timeline — the drained backlog
measured at each sample is redistributed over the window — so the async
dispatch pipeline survives between samples (ROADMAP item; ``N=1`` is the
exact PR-4 behavior).

Clock state is training state: the time-based AdaComm schedule continues
*mid-block* across a checkpoint/restore, so ``state_dict`` /
``load_state_dict`` ride ``checkpoint/io.py`` next to the strategy state.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax

from repro.core.comm_model import GBPS_10, GBPS_100, LATENCY_S, comm_time

# program names the backends charge as per-step compute (the local or
# fused-gradient step); everything else is sync machinery
STEP_PROGRAMS = ("replica_step", "full_step", "qsgd_step")


# ---------------------------------------------------------------------------
# Network model
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class NetworkModel:
    """The simulated link: the paper's 100 Gbps InfiniBand vs. the
    throttled 10 Gbps, plus the fast in-pod link hierarchical inner syncs
    ride (``intra_bandwidth``, defaults to the cross-pod bandwidth)."""

    name: str = "100gbps"
    bandwidth: float = GBPS_100          # bytes/s, cross-replica link
    latency_s: float = LATENCY_S         # per hop (comm_model.LATENCY_S)
    intra_bandwidth: Optional[float] = None   # in-pod link (inner_mean)

    @property
    def intra(self) -> float:
        return self.intra_bandwidth or self.bandwidth


_NETS = {
    "10gbps": NetworkModel("10gbps", GBPS_10),
    "100gbps": NetworkModel("100gbps", GBPS_100),
}


def resolve_net(spec) -> NetworkModel:
    """``'10gbps'`` / ``'100gbps'`` / ``'<x>gbps'`` / NetworkModel."""
    if isinstance(spec, NetworkModel):
        return spec
    s = str(spec).lower()
    if s in _NETS:
        return _NETS[s]
    if s.endswith("gbps"):
        return NetworkModel(s, float(s[:-4]) * 1e9 / 8)
    raise ValueError(f"unknown network '{spec}'; "
                     f"use one of {sorted(_NETS)} or '<x>gbps'")


# ---------------------------------------------------------------------------
# Timeline
# ---------------------------------------------------------------------------


@dataclass
class ProgramTiming:
    """One program invocation's cost report."""

    name: str                 # program name ("all_mean", "replica_step", …)
    step: int                 # engine iteration the dispatch belonged to
    compute_s: float = 0.0
    comm_s: float = 0.0
    bytes: float = 0.0        # modeled bytes per node moved by the program
    t_start: float = 0.0      # clock coordinates
    t_end: float = 0.0
    overlap: bool = False     # dispatched off the step path (InFlightOp);
                              # its cost is settled at fetch, not here
    interpolated: bool = False  # sampled-WallClock estimate, not a direct
                                # block-until-ready measurement


class Timeline:
    """Per-invocation ``ProgramTiming`` records plus running aggregates.

    Carried by ``TrainerEngine`` (``engine.timeline``); the engine stamps
    ``timeline.step`` before each iteration's dispatches.  Aggregates are
    O(1) per record; the record list itself is what benchmarks and tests
    introspect (bounded runs — cap or sample externally for very long
    ones)."""

    def __init__(self):
        self.records: List[ProgramTiming] = []
        self.step = 0
        self.compute_s = 0.0
        self.comm_s = 0.0
        self.bytes = 0.0
        self.by_program: Dict[str, Dict[str, float]] = {}

    def record(self, t: ProgramTiming) -> None:
        self.records.append(t)
        self.compute_s += t.compute_s
        self.comm_s += t.comm_s
        self.bytes += t.bytes
        agg = self.by_program.setdefault(
            t.name, {"calls": 0, "compute_s": 0.0, "comm_s": 0.0,
                     "bytes": 0.0})
        agg["calls"] += 1
        agg["compute_s"] += t.compute_s
        agg["comm_s"] += t.comm_s
        agg["bytes"] += t.bytes

    def amend(self, t: ProgramTiming, *, d_compute: float = 0.0,
              d_comm: float = 0.0) -> None:
        """Retroactively adjust an already-recorded timing (the sampled
        WallClock redistributes each drained backlog over its window's
        interpolated records), keeping the running aggregates consistent."""
        t.compute_s += d_compute
        t.comm_s += d_comm
        t.t_end += d_compute + d_comm
        self.compute_s += d_compute
        self.comm_s += d_comm
        agg = self.by_program[t.name]
        agg["compute_s"] += d_compute
        agg["comm_s"] += d_comm

    @property
    def last(self) -> Optional[ProgramTiming]:
        return self.records[-1] if self.records else None

    @property
    def total_s(self) -> float:
        return self.compute_s + self.comm_s

    def summary(self) -> Dict[str, Any]:
        return {"compute_s": self.compute_s, "comm_s": self.comm_s,
                "total_s": self.total_s, "bytes": self.bytes,
                "n_records": len(self.records),
                "by_program": {k: dict(v)
                               for k, v in self.by_program.items()}}


# ---------------------------------------------------------------------------
# Clocks
# ---------------------------------------------------------------------------


class Clock:
    """Base: owns the ``Timeline``; concrete clocks implement ``now`` and
    ``measure`` (called by ``ExecutionBackend.timed`` wrappers)."""

    kind = "base"

    def __init__(self):
        self.timeline = Timeline()

    def now(self) -> float:
        raise NotImplementedError

    def straggler_factor(self) -> float:
        """Slowest-replica slowdown (>= 1) the wall-clock AdaComm
        controller rescales its period by; 1 when unknown/homogeneous."""
        return 1.0

    def comm_cost(self, comm_bytes: float, collective: Optional[str],
                  n_nodes: int) -> float:
        """Modeled seconds for one collective of ``comm_bytes`` per node
        over ``n_nodes`` — 0 unless the clock simulates a network."""
        return 0.0

    def measure(self, name: str, fn, args, *, is_step: bool,
                comm_bytes: float = 0.0, collective: Optional[str] = None,
                n_nodes: int = 1):
        """Run program ``fn(*args)`` and record one ``ProgramTiming``.
        ``comm_bytes``/``collective``/``n_nodes`` are the backend's modeled
        communication shape for this invocation (``collective=None`` for
        collective-free programs)."""
        raise NotImplementedError

    # ------------------------------------------------------------- overlap
    def dispatch_async(self, name: str, fn, args, *,
                       comm_bytes: float = 0.0,
                       collective: Optional[str] = None,
                       n_nodes: int = 1) -> Tuple[Any, Optional[ProgramTiming]]:
        """Dispatch an ``overlap=True`` collective without blocking the
        step path; returns ``(outputs, record)`` — the record is handed
        back to ``complete_async`` when the caller fetches the
        ``InFlightOp``.  Base clocks without overlap support fall back to
        a synchronous ``measure`` (the op still runs, just un-overlapped)."""
        out = self.measure(name, fn, args, is_step=False,
                           comm_bytes=comm_bytes, collective=collective,
                           n_nodes=n_nodes)
        return out, None

    def complete_async(self, name: str, record: Optional[ProgramTiming],
                       outputs=None) -> None:
        """Settle a previously dispatched overlap op at fetch time: charge
        whatever part of the exchange compute did *not* hide.  Base: the
        fallback dispatch already paid in full."""

    # clock state is training state (the time-based AdaComm block schedule
    # must continue mid-block across restore) — see checkpoint/io.py
    def state_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "t": self.now()}

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        raise NotImplementedError


class WallClock(Clock):
    """Real elapsed time: ``time.monotonic()`` around dispatched,
    block-until-ready program calls.  ``load_state_dict`` re-bases the
    epoch so a restored run's ``now()`` continues from the saved time.

    ``sample_every=N`` (default 1 = block every dispatch, the PR-4
    behavior) blocks only on engine steps where ``step % N == 0`` and
    records *interpolated* timings in between: unsampled dispatches return
    immediately (the async pipeline stays N steps deep) and get the last
    sampled duration for their program as a provisional value; when the
    next sample blocks, the real elapsed time since the previous sample —
    which includes the window's drained backlog — is redistributed across
    the window's interpolated records (``Timeline.amend``), *replacing*
    the provisional values in both directions, so a compile-inflated
    early sample can never poison later windows: per-window totals equal
    real wall time, per-record values are interpolations and say so
    (``ProgramTiming.interpolated``).  ``n_blocks`` counts the actual
    block-until-ready calls (tests assert the sampling really happened)."""

    kind = "wall"

    def __init__(self, *, sample_every: int = 1):
        super().__init__()
        self.sample_every = max(1, int(sample_every))
        self._start = time.monotonic()
        self._base = 0.0
        self.n_blocks = 0
        self._est: Dict[str, float] = {}      # last sampled dt per program
        self._mark: Optional[float] = None    # end of the last sampled block
        # interpolated records since the last sampled block: (record, is_step)
        self._window: List[Tuple[ProgramTiming, bool]] = []

    @property
    def defer_loss_readback(self) -> bool:
        """The engine's per-step ``float(loss)`` read-back would re-sync
        the pipeline this clock is trying to keep async — ask it to defer
        host conversion to run end when sampling."""
        return self.sample_every > 1

    def now(self) -> float:
        return time.monotonic() - self._start + self._base

    def _record(self, name, dt, *, is_step, comm_bytes, t0,
                interpolated=False):
        rec = ProgramTiming(
            name=name, step=self.timeline.step,
            compute_s=dt if is_step else 0.0,
            comm_s=0.0 if is_step else dt,
            bytes=comm_bytes, t_start=t0, t_end=t0 + dt,
            interpolated=interpolated)
        self.timeline.record(rec)
        return rec

    def measure(self, name, fn, args, *, is_step, comm_bytes=0.0,
                collective=None, n_nodes=1):
        t0 = self.now()
        out = fn(*args)
        if self.sample_every > 1 and self.timeline.step % self.sample_every:
            # unsampled: keep the pipeline async, interpolate from the
            # last sample and reconcile at the next one
            rec = self._record(name, self._est.get(name, 0.0),
                               is_step=is_step, comm_bytes=comm_bytes,
                               t0=t0, interpolated=True)
            self._window.append((rec, is_step))
            return out
        jax.block_until_ready(out)
        self.n_blocks += 1
        t1 = self.now()
        dt = t1 - t0
        own = dt
        if self.sample_every > 1:
            if self._mark is None:
                self._mark = t0
            # real elapsed time since the previous sampled block — it
            # covers the whole unsampled window (whose async backlog
            # drained inside this block) plus this program's own run
            elapsed = t1 - self._mark
            self._mark = t1
            est = self._est.get(name)
            if self._window:
                own = min(dt, est) if est is not None else dt
                # rescale the window's provisional records to the real
                # remainder, proportionally to their estimates — replaces
                # over- and under-estimates alike (no one-way drift)
                target = max(0.0, elapsed - own)
                total = sum(r.compute_s + r.comm_s for r, _ in self._window)
                n = len(self._window)
                for r, r_is_step in self._window:
                    w = ((r.compute_s + r.comm_s) / total if total > 0
                         else 1.0 / n)
                    d = w * target - (r.compute_s + r.comm_s)
                    self.timeline.amend(r, d_compute=d if r_is_step else 0.0,
                                        d_comm=0.0 if r_is_step else d)
                self._window = []
            self._est[name] = own
        # a fused program can't split compute from comm: attribute the
        # measurement to the program's primary cost (docstring above)
        self._record(name, own, is_step=is_step, comm_bytes=comm_bytes, t0=t0)
        return out

    # ------------------------------------------------------------- overlap
    def dispatch_async(self, name, fn, args, *, comm_bytes=0.0,
                       collective=None, n_nodes=1):
        t0 = self.now()
        out = fn(*args)                   # async dispatch preserved
        rec = ProgramTiming(name=name, step=self.timeline.step,
                            bytes=comm_bytes, t_start=t0, t_end=t0,
                            overlap=True)
        self.timeline.record(rec)
        return out, rec

    def complete_async(self, name, record, outputs=None):
        t0 = self.now()
        if outputs is not None:
            jax.block_until_ready(outputs)
            self.n_blocks += 1
        dt = self.now() - t0
        if record is not None:
            record.t_end = t0 + dt        # the exchange was done by here
        # the observed stall — what the overlap did NOT manage to hide.
        # Unlike the SimulatedClock, the dispatch record carried no cost
        # (wall time of an un-awaited dispatch is unknowable), so this is
        # the exchange's single charge in the aggregates.
        self.timeline.record(ProgramTiming(
            name=f"{name}.fetch", step=self.timeline.step, comm_s=dt,
            t_start=t0, t_end=t0 + dt))
        if self._mark is not None:
            # sampled mode: this stall is already charged above — exclude
            # it from the next window's elapsed span, or the reconciliation
            # would hand the same seconds to the interpolated records too
            self._mark += dt

    def load_state_dict(self, state):
        self._base = float(state.get("t", 0.0))
        self._start = time.monotonic()


class SimulatedClock(Clock):
    """Deterministic time: compute charged per step program, communication
    charged from the per-collective analytic model.  Never blocks — the
    async dispatch pipeline is untouched and results are bit-identical to
    an un-clocked run."""

    kind = "sim"

    def __init__(self, net="100gbps", *, step_compute_s: float = 5e-3,
                 straggler: float = 1.0):
        super().__init__()
        self.net = resolve_net(net)
        self.step_compute_s = float(step_compute_s)
        if straggler < 1.0:
            raise ValueError("straggler slowdown must be >= 1")
        self.straggler = float(straggler)
        self._t = 0.0

    def now(self) -> float:
        return self._t

    def straggler_factor(self) -> float:
        return self.straggler

    def comm_cost(self, comm_bytes, collective, n_nodes):
        if collective is None or n_nodes <= 1:
            return 0.0
        bw = self.net.intra if collective == "inner_mean" else \
            self.net.bandwidth
        return comm_time(comm_bytes, 1, n_nodes, bw, collective=collective,
                         latency_s=self.net.latency_s)

    def measure(self, name, fn, args, *, is_step, comm_bytes=0.0,
                collective=None, n_nodes=1):
        out = fn(*args)
        # every replica waits for the slowest one at the next collective,
        # so the charged compute is the straggler-stretched one
        compute = self.step_compute_s * self.straggler if is_step else 0.0
        comm_s = self.comm_cost(comm_bytes, collective, n_nodes)
        t0 = self._t
        self._t += compute + comm_s
        self.timeline.record(ProgramTiming(
            name=name, step=self.timeline.step, compute_s=compute,
            comm_s=comm_s, bytes=comm_bytes, t_start=t0, t_end=self._t))
        return out

    # ------------------------------------------------------------- overlap
    def dispatch_async(self, name, fn, args, *, comm_bytes=0.0,
                       collective=None, n_nodes=1):
        """The exchange rides a concurrent stream: its full cost is
        recorded (off-path, ``overlap=True``) with ``t_end`` marking when
        the wire would be done, but simulated time does NOT advance — the
        step path keeps computing underneath."""
        out = fn(*args)
        comm_s = self.comm_cost(comm_bytes, collective, n_nodes)
        rec = ProgramTiming(name=name, step=self.timeline.step,
                            comm_s=comm_s, bytes=comm_bytes,
                            t_start=self._t, t_end=self._t + comm_s,
                            overlap=True)
        self.timeline.record(rec)
        return out, rec

    def complete_async(self, name, record, outputs=None):
        """Fetch: advance simulated time by the un-overlapped remainder
        only.  If the local steps of the delay window took longer than the
        exchange, the wait is zero — the collective was fully hidden.  The
        fetch record shows the stall as its *duration* (t_start..t_end)
        with ``comm_s=0``: the exchange's full cost was already recorded
        at dispatch, so aggregates count the wire exactly once."""
        wait = 0.0
        if record is not None:
            wait = max(0.0, record.t_end - self._t)
            self._t += wait
        self.timeline.record(ProgramTiming(
            name=f"{name}.fetch", step=self.timeline.step,
            t_start=self._t - wait, t_end=self._t))

    def state_dict(self):
        d = super().state_dict()
        d["net"] = self.net.name
        return d

    def load_state_dict(self, state):
        self._t = float(state.get("t", 0.0))


def make_clock(spec, *, wallclock_sample_every: int = 1) -> Optional[Clock]:
    """Driver-flag resolution: ``None``/``'none'`` -> no clock,
    ``'real'``/``'wall'`` -> WallClock (``wallclock_sample_every=N`` blocks
    only every N-th step and interpolates in between), anything else ->
    SimulatedClock on that network (``'10gbps'``, ``'100gbps'``,
    ``'<x>gbps'``)."""
    if spec is None or isinstance(spec, Clock):
        return spec
    s = str(spec).lower()
    if s in ("", "none"):
        return None
    if s in ("real", "wall"):
        return WallClock(sample_every=wallclock_sample_every)
    return SimulatedClock(s)
