"""Telemetry clocks: wall-clock and simulated time for the training loop.

The paper's headline claims are *wall-clock* claims (1.14-1.27x over
full-communication SGD at 100 Gbps, 1.46-1.95x at 10 Gbps), so time is a
first-class engine citizen.  A ``Clock`` is bound to the
``ExecutionBackend`` (``backend.set_clock``); every compiled program the
backend hands a strategy is wrapped by ``backend.timed(...)`` and reports
one ``ProgramTiming`` — ``(compute_s, comm_s, bytes)`` — per invocation
into the clock's ``Timeline`` (DESIGN.md §6).

Two implementations:

* ``WallClock``      — real ``time.monotonic()`` around dispatched,
  block-until-ready program calls.  A fused program (``full_step``) cannot
  split its measured time, so the whole measurement is attributed to the
  program's *primary* cost: compute for step programs, communication for
  sync programs; the modeled bytes ride along either way.
* ``SimulatedClock`` — never blocks and never reads the host clock.
  Compute is charged from a per-step cost (``step_compute_s``, times the
  ``straggler`` slowdown — the block waits for the slowest replica) and
  communication from ``core/comm_model.py``'s per-collective
  ``comm_time`` under a configurable ``NetworkModel`` (``10gbps`` /
  ``100gbps`` / any ``<x>gbps``).  Simulated time is a pure function of
  the dispatch sequence, so timing-dependent behavior (the wall-clock
  AdaComm controller, the bench-regression gate) is bit-reproducible on
  CPU CI.

Clock state is training state: the time-based AdaComm schedule continues
*mid-block* across a checkpoint/restore, so ``state_dict`` /
``load_state_dict`` ride ``checkpoint/io.py`` next to the strategy state.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

from repro.core.comm_model import GBPS_10, GBPS_100, LATENCY_S, comm_time

# program names the backends charge as per-step compute (the local or
# fused-gradient step); everything else is sync machinery
STEP_PROGRAMS = ("replica_step", "full_step", "qsgd_step")


# ---------------------------------------------------------------------------
# Network model
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class NetworkModel:
    """The simulated link: the paper's 100 Gbps InfiniBand vs. the
    throttled 10 Gbps, plus the fast in-pod link hierarchical inner syncs
    ride (``intra_bandwidth``, defaults to the cross-pod bandwidth)."""

    name: str = "100gbps"
    bandwidth: float = GBPS_100          # bytes/s, cross-replica link
    latency_s: float = LATENCY_S         # per hop (comm_model.LATENCY_S)
    intra_bandwidth: Optional[float] = None   # in-pod link (inner_mean)

    @property
    def intra(self) -> float:
        return self.intra_bandwidth or self.bandwidth


_NETS = {
    "10gbps": NetworkModel("10gbps", GBPS_10),
    "100gbps": NetworkModel("100gbps", GBPS_100),
}


def resolve_net(spec) -> NetworkModel:
    """``'10gbps'`` / ``'100gbps'`` / ``'<x>gbps'`` / NetworkModel."""
    if isinstance(spec, NetworkModel):
        return spec
    s = str(spec).lower()
    if s in _NETS:
        return _NETS[s]
    if s.endswith("gbps"):
        return NetworkModel(s, float(s[:-4]) * 1e9 / 8)
    raise ValueError(f"unknown network '{spec}'; "
                     f"use one of {sorted(_NETS)} or '<x>gbps'")


# ---------------------------------------------------------------------------
# Timeline
# ---------------------------------------------------------------------------


@dataclass
class ProgramTiming:
    """One program invocation's cost report."""

    name: str                 # program name ("all_mean", "replica_step", …)
    step: int                 # engine iteration the dispatch belonged to
    compute_s: float = 0.0
    comm_s: float = 0.0
    bytes: float = 0.0        # modeled bytes per node moved by the program
    t_start: float = 0.0      # clock coordinates
    t_end: float = 0.0


class Timeline:
    """Per-invocation ``ProgramTiming`` records plus running aggregates.

    Carried by ``TrainerEngine`` (``engine.timeline``); the engine stamps
    ``timeline.step`` before each iteration's dispatches.  Aggregates are
    O(1) per record; the record list itself is what benchmarks and tests
    introspect (bounded runs — cap or sample externally for very long
    ones)."""

    def __init__(self):
        self.records: List[ProgramTiming] = []
        self.step = 0
        self.compute_s = 0.0
        self.comm_s = 0.0
        self.bytes = 0.0
        self.by_program: Dict[str, Dict[str, float]] = {}

    def record(self, t: ProgramTiming) -> None:
        self.records.append(t)
        self.compute_s += t.compute_s
        self.comm_s += t.comm_s
        self.bytes += t.bytes
        agg = self.by_program.setdefault(
            t.name, {"calls": 0, "compute_s": 0.0, "comm_s": 0.0,
                     "bytes": 0.0})
        agg["calls"] += 1
        agg["compute_s"] += t.compute_s
        agg["comm_s"] += t.comm_s
        agg["bytes"] += t.bytes

    @property
    def last(self) -> Optional[ProgramTiming]:
        return self.records[-1] if self.records else None

    @property
    def total_s(self) -> float:
        return self.compute_s + self.comm_s

    def summary(self) -> Dict[str, Any]:
        return {"compute_s": self.compute_s, "comm_s": self.comm_s,
                "total_s": self.total_s, "bytes": self.bytes,
                "n_records": len(self.records),
                "by_program": {k: dict(v)
                               for k, v in self.by_program.items()}}


# ---------------------------------------------------------------------------
# Clocks
# ---------------------------------------------------------------------------


class Clock:
    """Base: owns the ``Timeline``; concrete clocks implement ``now`` and
    ``measure`` (called by ``ExecutionBackend.timed`` wrappers)."""

    kind = "base"

    def __init__(self):
        self.timeline = Timeline()

    def now(self) -> float:
        raise NotImplementedError

    def straggler_factor(self) -> float:
        """Slowest-replica slowdown (>= 1) the wall-clock AdaComm
        controller rescales its period by; 1 when unknown/homogeneous."""
        return 1.0

    def comm_cost(self, comm_bytes: float, collective: Optional[str],
                  n_nodes: int) -> float:
        """Modeled seconds for one collective of ``comm_bytes`` per node
        over ``n_nodes`` — 0 unless the clock simulates a network."""
        return 0.0

    def measure(self, name: str, fn, args, *, is_step: bool,
                comm_bytes: float = 0.0, collective: Optional[str] = None,
                n_nodes: int = 1):
        """Run program ``fn(*args)`` and record one ``ProgramTiming``.
        ``comm_bytes``/``collective``/``n_nodes`` are the backend's modeled
        communication shape for this invocation (``collective=None`` for
        collective-free programs)."""
        raise NotImplementedError

    # clock state is training state (the time-based AdaComm block schedule
    # must continue mid-block across restore) — see checkpoint/io.py
    def state_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "t": self.now()}

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        raise NotImplementedError


class WallClock(Clock):
    """Real elapsed time: ``time.monotonic()`` around dispatched,
    block-until-ready program calls.  ``load_state_dict`` re-bases the
    epoch so a restored run's ``now()`` continues from the saved time."""

    kind = "wall"

    def __init__(self):
        super().__init__()
        self._start = time.monotonic()
        self._base = 0.0

    def now(self) -> float:
        return time.monotonic() - self._start + self._base

    def measure(self, name, fn, args, *, is_step, comm_bytes=0.0,
                collective=None, n_nodes=1):
        import jax
        t0 = self.now()
        out = fn(*args)
        jax.block_until_ready(out)
        dt = self.now() - t0
        # a fused program can't split compute from comm: attribute the
        # measurement to the program's primary cost (docstring above)
        self.timeline.record(ProgramTiming(
            name=name, step=self.timeline.step,
            compute_s=dt if is_step else 0.0,
            comm_s=0.0 if is_step else dt,
            bytes=comm_bytes, t_start=t0, t_end=t0 + dt))
        return out

    def load_state_dict(self, state):
        self._base = float(state.get("t", 0.0))
        self._start = time.monotonic()


class SimulatedClock(Clock):
    """Deterministic time: compute charged per step program, communication
    charged from the per-collective analytic model.  Never blocks — the
    async dispatch pipeline is untouched and results are bit-identical to
    an un-clocked run."""

    kind = "sim"

    def __init__(self, net="100gbps", *, step_compute_s: float = 5e-3,
                 straggler: float = 1.0):
        super().__init__()
        self.net = resolve_net(net)
        self.step_compute_s = float(step_compute_s)
        if straggler < 1.0:
            raise ValueError("straggler slowdown must be >= 1")
        self.straggler = float(straggler)
        self._t = 0.0

    def now(self) -> float:
        return self._t

    def straggler_factor(self) -> float:
        return self.straggler

    def comm_cost(self, comm_bytes, collective, n_nodes):
        if collective is None or n_nodes <= 1:
            return 0.0
        bw = self.net.intra if collective == "inner_mean" else \
            self.net.bandwidth
        return comm_time(comm_bytes, 1, n_nodes, bw, collective=collective,
                         latency_s=self.net.latency_s)

    def measure(self, name, fn, args, *, is_step, comm_bytes=0.0,
                collective=None, n_nodes=1):
        out = fn(*args)
        # every replica waits for the slowest one at the next collective,
        # so the charged compute is the straggler-stretched one
        compute = self.step_compute_s * self.straggler if is_step else 0.0
        comm_s = self.comm_cost(comm_bytes, collective, n_nodes)
        t0 = self._t
        self._t += compute + comm_s
        self.timeline.record(ProgramTiming(
            name=name, step=self.timeline.step, compute_s=compute,
            comm_s=comm_s, bytes=comm_bytes, t_start=t0, t_end=self._t))
        return out

    def state_dict(self):
        d = super().state_dict()
        d["net"] = self.net.name
        return d

    def load_state_dict(self, state):
        self._t = float(state.get("t", 0.0))


def make_clock(spec) -> Optional[Clock]:
    """Driver-flag resolution: ``None``/``'none'`` -> no clock,
    ``'real'``/``'wall'`` -> WallClock, anything else -> SimulatedClock
    on that network (``'10gbps'``, ``'100gbps'``, ``'<x>gbps'``)."""
    if spec is None or isinstance(spec, Clock):
        return spec
    s = str(spec).lower()
    if s in ("", "none"):
        return None
    if s in ("real", "wall"):
        return WallClock()
    return SimulatedClock(s)
