"""Back-compat shim over the strategy-driven engine.

The seed's ``train_periodic`` (one loop, per-method string branches) is
replaced by ``runtime/engine.py``'s ``TrainerEngine`` + the pluggable
``repro/strategies`` registry.  This module keeps the old entry point for
one release: it builds an engine and runs it.  New code should construct
``TrainerEngine`` directly.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax.numpy as jnp

from repro.configs.base import AveragingConfig
from repro.core.controller import PeriodController
from repro.optim.optimizers import Optimizer
from repro.runtime.engine import (  # noqa: F401  (re-exported API)
    TrainerEngine, TrainHistory, evaluate,
)
from repro.strategies import make_strategy
from repro.strategies.periodic import PeriodicAveragingStrategy

Pytree = Any


def train_periodic(*,
                   loss_fn,
                   optimizer: Optimizer,
                   params0: Pytree,
                   n_replicas: int,
                   data_fn: Callable[[int], Dict[str, jnp.ndarray]],
                   lr_fn: Callable[[int], float],
                   avg_cfg: AveragingConfig,
                   total_steps: int,
                   track_variance_every: int = 0,
                   seed: int = 0,
                   controller: Optional[PeriodController] = None,
                   ) -> TrainHistory:
    """Deprecated: delegate to ``TrainerEngine`` via the strategy registry.
    ``controller``, if given, is installed into the strategy (periodic
    strategies only) so callers that pre-built one keep working."""
    strategy = make_strategy(avg_cfg, total_steps)
    if controller is not None and isinstance(strategy, PeriodicAveragingStrategy):
        # every-step strategies (fullsgd/qsgd) never consulted the
        # controller in the seed loop either — ignore it for those.
        strategy.set_controller(controller)
    engine = TrainerEngine(
        loss_fn=loss_fn, optimizer=optimizer, params0=params0,
        n_replicas=n_replicas, data_fn=data_fn, lr_fn=lr_fn,
        avg_cfg=avg_cfg, total_steps=total_steps, strategy=strategy,
        track_variance_every=track_variance_every, seed=seed)
    return engine.run()
