"""Training loop: controller-dispatched periodic averaging.

One loop serves every method in the paper (FULLSGD / CPSGD / ADPSGD /
QSGD / decreasing-period): the controller decides when the sync program
runs; the loop records losses, the variance probe S_k, the period
trajectory (paper Fig 3) and, optionally, the per-iteration parameter
variance Var[W_k] (paper Fig 1/2).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AveragingConfig
from repro.core import averaging as avg
from repro.core import qsgd as qsgd_mod
from repro.core.controller import PeriodController, make_controller
from repro.optim.optimizers import Optimizer

Pytree = Any


@dataclass
class TrainHistory:
    method: str
    losses: List[float] = field(default_factory=list)
    variances: List[float] = field(default_factory=list)       # Var[W_k] samples
    variance_steps: List[int] = field(default_factory=list)
    s_k: List[float] = field(default_factory=list)             # probe at syncs
    sync_steps: List[int] = field(default_factory=list)
    period_history: List[int] = field(default_factory=list)
    lrs: List[float] = field(default_factory=list)
    wall_s: float = 0.0
    n_syncs: int = 0
    final_W: Optional[Pytree] = None
    final_opt: Optional[Pytree] = None

    def weighted_avg_variance(self) -> float:
        """Paper Eq. 9: Σ γ_k Var[W_k] / Σ γ_j over the sampled steps."""
        if not self.variances:
            return 0.0
        g = np.array([self.lrs[min(s, len(self.lrs) - 1)]
                      for s in self.variance_steps])
        return float(np.sum(g * np.array(self.variances)) / np.sum(g))


def train_periodic(*,
                   loss_fn,
                   optimizer: Optimizer,
                   params0: Pytree,
                   n_replicas: int,
                   data_fn: Callable[[int], Dict[str, jnp.ndarray]],
                   lr_fn: Callable[[int], float],
                   avg_cfg: AveragingConfig,
                   total_steps: int,
                   track_variance_every: int = 0,
                   seed: int = 0,
                   controller: Optional[PeriodController] = None,
                   ) -> TrainHistory:
    """Simulates n_replicas local-SGD workers (stacked replica axis — on one
    device for experiments, sharded over the mesh in production)."""
    ctrl = controller or make_controller(avg_cfg, total_steps)
    W = avg.stack_replicas(params0, n_replicas)
    opt_state = jax.vmap(optimizer.init)(W)

    local_step = jax.jit(avg.make_local_step(loss_fn, optimizer))
    full_step = jax.jit(avg.make_full_step(loss_fn, optimizer))
    qsgd_step = jax.jit(qsgd_mod.make_qsgd_step(
        loss_fn, optimizer, avg_cfg.qsgd_bits))
    sync = jax.jit(lambda W, o: avg.sync_replicas(
        W, o, sync_momentum=avg_cfg.sync_momentum))
    var_fn = jax.jit(avg.parameter_variance)

    hist = TrainHistory(method=avg_cfg.method)
    key = jax.random.PRNGKey(seed + 17)
    t0 = time.time()
    for k in range(total_steps):
        lr = lr_fn(k)
        hist.lrs.append(lr)
        batch = data_fn(k)
        if avg_cfg.method == "qsgd":
            key, sub = jax.random.split(key)
            W, opt_state, metrics = qsgd_step(W, opt_state, batch, lr, sub)
        elif avg_cfg.method == "fullsgd":
            W, opt_state, metrics = full_step(W, opt_state, batch, lr)
        else:
            W, opt_state, metrics = local_step(W, opt_state, batch, lr)
        hist.losses.append(float(metrics["loss"]))

        if track_variance_every and (k % track_variance_every == 0):
            hist.variances.append(float(var_fn(W)))
            hist.variance_steps.append(k)

        if avg_cfg.method not in ("fullsgd", "qsgd") and ctrl.sync_now(k):
            W, opt_state, s_k = sync(W, opt_state)
            s_k = float(s_k)
            ctrl.observe(k, lr, s_k)
            hist.s_k.append(s_k)
            hist.sync_steps.append(k)
            hist.period_history.append(ctrl.period)
    hist.wall_s = time.time() - t0
    hist.n_syncs = len(hist.sync_steps) if avg_cfg.method not in (
        "fullsgd", "qsgd") else total_steps
    hist.final_W = W
    hist.final_opt = opt_state
    return hist


def evaluate(loss_fn, W: Pytree, batches) -> Dict[str, float]:
    """Evaluate the replica-averaged model."""
    params = avg.replica_mean(W)
    f = jax.jit(loss_fn)
    tot: Dict[str, float] = {}
    n = 0
    for b in batches:
        _, aux = f(params, b)
        for kk, v in aux.items():
            tot[kk] = tot.get(kk, 0.0) + float(v)
        n += 1
    return {k: v / max(n, 1) for k, v in tot.items()}
