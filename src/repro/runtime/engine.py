"""Strategy-agnostic training engine.

``TrainerEngine`` owns the training state (replica-stacked parameters W,
optimizer state, history) and the iteration loop; *everything*
method-specific lives in the ``CommunicationStrategy`` it is given (see
``repro/strategies/base.py``), and everything device-specific in the
``ExecutionBackend`` the strategy compiles against
(``repro/backends/base.py`` — vmap on one host device, or shard_map over a
real mesh).  Per iteration the engine asks the strategy which pre-compiled
programs to dispatch (``strategy.actions(k)``), runs them, and routes their
outputs:

* ``info["loss"]``       -> training-loss sample
* ``info["s_k"]``        -> a sync happened: feed ``strategy.observe`` and
                            record the probe / period trajectory
* ``info["s_k_at"]``     -> ``(step, s_k)``: a sync whose probe was fetched
                            *later* than it was measured (DaSGD's overlapped
                            snapshot) — recorded against its snapshot step
* ``info["inner_sync"]`` -> hierarchical inner-sync marker

A small callback bus hangs off the loop (variance probing, periodic eval,
checkpointing); callbacks never influence the dispatch decision, so the
control path stays as lean as the seed loop's.

RNG keys are derived statelessly (``fold_in(base, k); fold_in(·, j)``), so a
checkpoint-resumed run replays the identical key stream from any step.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.backends import ExecutionBackend, resolve_backend
from repro.configs.base import AveragingConfig
from repro.core import averaging as avg
from repro.runtime.clock import Clock, Timeline
from repro.strategies import CommunicationStrategy, make_strategy

Pytree = Any


@dataclass
class TrainHistory:
    method: str
    losses: List[float] = field(default_factory=list)
    variances: List[float] = field(default_factory=list)       # Var[W_k] samples
    variance_steps: List[int] = field(default_factory=list)
    s_k: List[float] = field(default_factory=list)             # probe at syncs
    sync_steps: List[int] = field(default_factory=list)
    period_history: List[int] = field(default_factory=list)
    inner_sync_steps: List[int] = field(default_factory=list)  # hierarchical
    lrs: List[float] = field(default_factory=list)
    lr_start_step: int = 0        # absolute step of lrs[0] (resumed runs)
    evals: List[Dict[str, float]] = field(default_factory=list)
    eval_steps: List[int] = field(default_factory=list)
    wall_s: float = 0.0
    n_syncs: int = 0
    # telemetry (runtime/clock.py): Timeline.summary() of the run when the
    # engine carried a clock — measured (wall) or simulated per-program
    # compute/comm seconds and modeled bytes; None on un-clocked runs
    timing: Optional[Dict[str, Any]] = None
    final_W: Optional[Pytree] = None
    final_opt: Optional[Pytree] = None

    def weighted_avg_variance(self) -> float:
        """Paper Eq. 9: Σ γ_k Var[W_k] / Σ γ_j over the sampled steps."""
        if not self.variances:
            return 0.0
        idx = np.clip(np.array(self.variance_steps) - self.lr_start_step,
                      0, len(self.lrs) - 1)
        g = np.array(self.lrs)[idx]
        return float(np.sum(g * np.array(self.variances)) / np.sum(g))


# ---------------------------------------------------------------------------
# Callback bus
# ---------------------------------------------------------------------------


class Callback:
    """Hook points on the engine loop.  Override what you need.

    ``on_step_end`` fires after the step program but *before* any sync of
    the same iteration — the place to observe pre-sync replica drift (paper
    Fig 1/2).  ``on_iteration_end`` fires once all of iteration k's
    programs ran — the place for anything that must see a consistent
    (post-sync) snapshot, e.g. checkpointing or eval."""

    def on_step_end(self, engine: "TrainerEngine", k: int,
                    metrics: Dict[str, Any]) -> None:
        """On clocked runs ``metrics["timing"]`` carries the step program's
        ``ProgramTiming`` (compute_s/comm_s/bytes — runtime/clock.py)."""
        pass

    def on_sync(self, engine: "TrainerEngine", k: int, s_k: float,
                timing=None) -> None:
        """``timing`` is the sync program's ``ProgramTiming`` on clocked
        runs (None otherwise) — comm_s/bytes of this exchange."""
        pass

    def on_iteration_end(self, engine: "TrainerEngine", k: int,
                         metrics: Dict[str, Any]) -> None:
        pass

    def on_run_end(self, engine: "TrainerEngine") -> None:
        pass


class VarianceProbe(Callback):
    """Sample Var[W_k] (paper Eq. 7 / Fig 1-2) every ``every`` steps."""

    def __init__(self, every: int):
        self.every = max(1, every)
        self._fn = jax.jit(avg.parameter_variance)

    def on_step_end(self, engine, k, metrics):
        if k % self.every == 0:
            engine.history.variances.append(float(self._fn(engine.W)))
            engine.history.variance_steps.append(k)


class PeriodicEval(Callback):
    """Evaluate the replica-averaged model every ``every`` steps."""

    def __init__(self, loss_fn, batches_fn: Callable[[], Iterable],
                 every: int):
        self.loss_fn = loss_fn
        self.batches_fn = batches_fn
        self.every = max(1, every)

    def on_iteration_end(self, engine, k, metrics):
        if (k + 1) % self.every == 0:
            ev = evaluate(self.loss_fn, engine.W, self.batches_fn())
            engine.history.evals.append(ev)
            engine.history.eval_steps.append(k)


class Checkpointer(Callback):
    """Save (W, opt_state, strategy state) every ``every`` steps, so a
    restored run continues the identical sync schedule (DESIGN.md §4).

    ``keep_replicas=False`` collapses W to the replica mean — an *export*
    checkpoint for serving/eval, not resumable through
    ``TrainerEngine.load_state`` (which needs the stacked replica axis)."""

    def __init__(self, path: str, every: int, keep_replicas: bool = True):
        self.path = path
        self.every = max(1, every)
        self.keep_replicas = keep_replicas

    def on_iteration_end(self, engine, k, metrics):
        # must run after any sync of iteration k: the saved W has to be
        # consistent with the saved (post-observe) strategy state
        if (k + 1) % self.every == 0:
            self.save(engine, k + 1)

    def save(self, engine: "TrainerEngine", step: int) -> None:
        from repro.checkpoint.io import save_checkpoint, strategy_state
        W = engine.W if self.keep_replicas else avg.replica_mean(engine.W)
        # export checkpoints drop the (replica-stacked) optimizer state too
        opt = engine.opt_state if self.keep_replicas else None
        save_checkpoint(self.path, W, opt_state=opt, step=step,
                        controller_state=strategy_state(engine.strategy),
                        clock_state=(engine.clock.state_dict()
                                     if engine.clock else None))


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


class TrainerEngine:
    """Owns state + loop; the strategy owns policy + programs."""

    def __init__(self, *,
                 loss_fn,
                 optimizer,
                 params0: Optional[Pytree] = None,
                 n_replicas: int = 1,
                 data_fn: Callable[[int], Dict[str, jnp.ndarray]],
                 lr_fn: Callable[[int], float],
                 total_steps: int,
                 avg_cfg: Optional[AveragingConfig] = None,
                 strategy: Optional[CommunicationStrategy] = None,
                 backend: Optional[ExecutionBackend] = None,
                 clock: Optional[Clock] = None,
                 callbacks: Sequence[Callback] = (),
                 track_variance_every: int = 0,
                 seed: int = 0):
        if strategy is None:
            if avg_cfg is None:
                raise ValueError("need avg_cfg or strategy")
            strategy = make_strategy(avg_cfg, total_steps)
        elif avg_cfg is not None and avg_cfg != strategy.cfg:
            # a conflicting avg_cfg would retune the programs but not the
            # already-constructed schedule state — refuse the drift
            raise ValueError(
                "avg_cfg conflicts with the explicit strategy's config; "
                "pass one or the other (or matching configs)")
        self.backend = resolve_backend(backend)   # name, instance, or None
        self.backend.bind(n_replicas)
        # telemetry: the clock rides the backend (every program the backend
        # builds is a timed wrapper) and its Timeline rides the engine
        self.clock = clock
        self.timeline: Optional[Timeline] = clock.timeline if clock else None
        # unconditional: clock=None must also *clear* any clock a previous
        # engine left bound on a reused backend instance
        self.backend.set_clock(clock)
        self.strategy = strategy
        self.strategy.compile(loss_fn, optimizer, backend=self.backend)
        self.strategy.bind_clock(clock)
        self._optimizer = optimizer
        self._n_replicas = n_replicas
        self.loss_fn = loss_fn
        self.data_fn = data_fn
        self.lr_fn = lr_fn
        self.total_steps = total_steps
        self.callbacks: List[Callback] = list(callbacks)
        if track_variance_every:
            self.callbacks.append(VarianceProbe(track_variance_every))
        self._base_key = jax.random.PRNGKey(seed + 17)
        self._comm_event_base = 0      # restored events don't count in
        self.history = TrainHistory(method=self.strategy.name)  # this history
        self.W: Optional[Pytree] = None
        self.opt_state: Optional[Pytree] = None
        if params0 is not None:
            self.W = self.backend.put_params(
                avg.stack_replicas(params0, n_replicas))
            self.opt_state = self.backend.init_opt_state(optimizer, self.W)

    # ------------------------------------------------------------------
    def load_state(self, W: Pytree, opt_state: Optional[Pytree] = None,
                   strategy_state: Optional[Dict] = None,
                   clock_state: Optional[Dict] = None) -> None:
        """Install checkpointed state (replica-stacked W) for resume.
        Export checkpoints (``Checkpointer(keep_replicas=False)``) lack the
        replica axis and are rejected.  State is re-``put`` through the
        active backend, so a checkpoint saved under one backend (vmap)
        resumes under another (mesh) and vice versa — ``checkpoint/io.py``
        always saves host arrays.  ``opt_state=None`` keeps the engine's
        freshly-initialized optimizer state — the schedule still resumes
        exactly, but stateful optimizers (momentum/adamw) restart from
        zero, so the loss trajectory is not bit-identical."""
        got = [tuple(np.shape(x)) for x in jax.tree_util.tree_leaves(W)]
        if self.W is not None:
            want = [x.shape for x in jax.tree_util.tree_leaves(self.W)]
        else:
            # no params0 reference: every leaf must still lead with the
            # replica axis this engine was constructed for
            want = [(self._n_replicas,) + s[1:] for s in got]
        if want != got:
            raise ValueError(
                "checkpoint does not match the engine's replica-stacked "
                "state (was it saved with keep_replicas=False? such "
                f"checkpoints are export-only): {got[:1]} vs {want[:1]}")
        self.W = self.backend.put_params(W)
        if opt_state is not None:
            self.opt_state = self.backend.put_opt(opt_state, self.W)
        elif self.opt_state is None:
            # checkpoint without opt_state on a params0-less engine: give
            # the run a fresh optimizer state (see docstring caveat)
            self.opt_state = self.backend.init_opt_state(
                self._optimizer, self.W)
        # clock before strategy: the restored controller's block-start is in
        # clock coordinates, so the clock must already tick from the saved
        # time when time-driven policies resume (mid-block schedules)
        if clock_state is not None and self.clock is not None:
            self.clock.load_state_dict(clock_state)
        if strategy_state is not None:
            from repro.checkpoint.io import restore_strategy
            restore_strategy(self.strategy, strategy_state)
        # keep n_syncs per-history: syncs before the restore belong to the
        # saved run's history, not this one
        self._comm_event_base = self.strategy.n_comm_events

    # ------------------------------------------------------------------
    def run(self, start_step: int = 0,
            num_steps: Optional[int] = None) -> TrainHistory:
        """Run iterations [start_step, start_step + num_steps).  Call again
        with the next ``start_step`` to continue (or resume after a
        restore) — the strategy's schedule state carries across calls."""
        if self.W is None:
            raise RuntimeError("no parameters: pass params0 or load_state()")
        stop = self.total_steps if num_steps is None \
            else min(self.total_steps, start_step + num_steps)
        hist = self.history
        if not hist.lrs:
            hist.lr_start_step = start_step
        t0 = time.time()
        tl = self.timeline
        # a sampled WallClock asks to keep the dispatch pipeline async:
        # per-step float(loss) read-back would re-sync it every iteration,
        # so losses stay device scalars until run end (values identical)
        defer_loss = bool(getattr(self.clock, "defer_loss_readback", False))

        def record_sync(at, lr_at, s_val, timing):
            """One sync event into history + controller + callbacks —
            shared by the immediate ("s_k") and the overlapped-settlement
            ("s_k_at") paths so they can never drift apart."""
            s_k = float(s_val)
            self.strategy.observe(at, lr_at, s_k)
            hist.s_k.append(s_k)
            hist.sync_steps.append(at)
            hist.period_history.append(self.strategy.period)
            for cb in self.callbacks:
                cb.on_sync(self, at, s_k, timing)

        for k in range(start_step, stop):
            lr = self.lr_fn(k)
            hist.lrs.append(lr)
            batch = self.data_fn(k)
            step_key = jax.random.fold_in(self._base_key, k)
            step_info: Dict[str, Any] = {}
            if tl is not None:
                tl.step = k          # dispatches below stamp this iteration
            for j, action in enumerate(self.strategy.actions(k)):
                key = jax.random.fold_in(step_key, j)
                self.W, self.opt_state, info = self.strategy.dispatch(
                    action, self.W, self.opt_state, batch, lr, key)
                timing = tl.last if tl is not None else None
                if "loss" in info:
                    step_info = info
                    loss_val = (info["loss"] if defer_loss
                                else float(info["loss"]))
                    hist.losses.append(loss_val)
                    self.strategy.observe_loss(k, loss_val)
                    if timing is not None:
                        info["timing"] = timing
                    for cb in self.callbacks:
                        cb.on_step_end(self, k, info)
                if "s_k" in info:
                    record_sync(k, lr, info["s_k"], timing)
                if "s_k_at" in info:
                    # an overlapped sync settled: the probe belongs to the
                    # snapshot iteration, not the fetch iteration — there
                    # is at most one exchange in flight (delay < period),
                    # so ordering within the history is preserved
                    at, s_val = info["s_k_at"]
                    at = int(at)
                    if tl is not None:
                        # on_sync's contract is the *exchange's* record
                        # (comm_s/bytes), which was written at dispatch —
                        # not the apply program's that tl.last holds now
                        timing = next(
                            (r for r in reversed(tl.records)
                             if r.overlap and r.step == at), timing)
                    record_sync(at, self.lr_fn(at), s_val, timing)
                if info.get("inner_sync"):
                    hist.inner_sync_steps.append(k)
            for cb in self.callbacks:
                cb.on_iteration_end(self, k, step_info)
        if defer_loss:
            hist.losses[:] = [float(v) for v in hist.losses]
        hist.wall_s += time.time() - t0
        hist.n_syncs = self.strategy.n_comm_events - self._comm_event_base
        if tl is not None:
            hist.timing = dict(tl.summary(), clock=self.clock.kind,
                               sim_wall_s=self.clock.now())
        hist.final_W = self.W
        hist.final_opt = self.opt_state
        for cb in self.callbacks:
            cb.on_run_end(self)
        return hist


def evaluate(loss_fn, W: Pytree, batches) -> Dict[str, float]:
    """Evaluate the replica-averaged model."""
    params = avg.replica_mean(W)
    f = jax.jit(loss_fn)
    tot: Dict[str, float] = {}
    n = 0
    for b in batches:
        _, aux = f(params, b)
        for kk, v in aux.items():
            tot[kk] = tot.get(kk, 0.0) + float(v)
        n += 1
    return {k: v / max(n, 1) for k, v in tot.items()}
