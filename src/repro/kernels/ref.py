"""Pure-jnp oracles for every Pallas kernel (the correctness references the
kernel tests sweep against)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B,S,H,d); k,v: (B,S,K,d).  Exact softmax attention."""
    B, Sq, H, d = q.shape
    _, Sk, K, _ = k.shape
    G = H // K
    qh = q.reshape(B, Sq, K, G, d)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qh.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kp <= qp
    if window:
        mask &= kp > qp - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, d).astype(q.dtype)


def quantize_ref(x, u, *, bits: int = 8):
    """QSGD with externally-supplied uniforms (same contract as the kernel)."""
    s = (1 << (bits - 1)) - 1
    xf = x.astype(jnp.float32)
    norm = jnp.sqrt(jnp.sum(jnp.square(xf)))
    scaled = jnp.where(norm > 0, jnp.abs(xf) / norm * s, 0.0)
    floor = jnp.floor(scaled)
    mag = floor + (u < (scaled - floor)).astype(jnp.float32)
    return (jnp.sign(xf) * mag).astype(jnp.int8), norm


def dequantize_ref(levels, norm, *, bits: int = 8):
    s = (1 << (bits - 1)) - 1
    return levels.astype(jnp.float32) * (norm / s)


def mean_and_sqdev_ref(w):
    """w: (R, ...) -> (mean over axis 0, Σ ||mean − w_i||²)."""
    wf = w.reshape(w.shape[0], -1).astype(jnp.float32)
    mean = jnp.mean(wf, axis=0)
    sq = jnp.sum(jnp.square(wf - mean[None]))
    return mean.reshape(w.shape[1:]), sq
