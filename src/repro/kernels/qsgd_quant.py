"""QSGD stochastic quantization Pallas kernels.

The QSGD baseline's hot spot is a bandwidth-bound elementwise pass over
every gradient buffer (quantize before transmit, dequantize after).  The
kernels stream 8/128-aligned VMEM tiles; the tensor L2 norm is computed by
a first reduction kernel, and the uniform randoms for stochastic rounding
are supplied as an input stream so the kernel is bit-exactly testable
against the jnp oracle."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024


def _sqsum_kernel(x_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    o_ref[0, 0] += jnp.sum(x * x)


def _quant_kernel(x_ref, u_ref, norm_ref, lv_ref, *, s: int):
    x = x_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    norm = norm_ref[0, 0]
    scaled = jnp.where(norm > 0, jnp.abs(x) * (s / norm), 0.0)
    floor = jnp.floor(scaled)
    mag = floor + (u < (scaled - floor)).astype(jnp.float32)
    lv_ref[...] = (jnp.sign(x) * mag).astype(jnp.int8)


def _dequant_kernel(lv_ref, norm_ref, o_ref, *, s: int):
    o_ref[...] = (lv_ref[...].astype(jnp.float32)
                  * (norm_ref[0, 0] / s)).astype(o_ref.dtype)


def _pad_flat(x: jnp.ndarray, block: int):
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat.reshape(-1, block), n


@functools.partial(jax.jit, static_argnames=("interpret",))
def sqnorm(x: jnp.ndarray, *, interpret: bool = False) -> jnp.ndarray:
    xb, _ = _pad_flat(x, BLOCK)
    nb = xb.shape[0]
    out = pl.pallas_call(
        _sqsum_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, BLOCK), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(xb)
    return out[0, 0]


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def quantize(x: jnp.ndarray, u: jnp.ndarray, *, bits: int = 8,
             interpret: bool = False):
    """x: any-shape tensor; u: uniforms of the same shape.  Returns
    (levels int8 of x.shape, norm scalar f32)."""
    s = (1 << (bits - 1)) - 1
    norm = jnp.sqrt(sqnorm(x, interpret=interpret)).reshape(1, 1)
    xb, n = _pad_flat(x, BLOCK)
    ub, _ = _pad_flat(u, BLOCK)
    nb = xb.shape[0]
    lv = pl.pallas_call(
        functools.partial(_quant_kernel, s=s),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, BLOCK), jnp.int8),
        interpret=interpret,
    )(xb, ub, norm)
    return lv.reshape(-1)[:n].reshape(x.shape), norm[0, 0]


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def dequantize(levels: jnp.ndarray, norm: jnp.ndarray, *, bits: int = 8,
               interpret: bool = False) -> jnp.ndarray:
    s = (1 << (bits - 1)) - 1
    lb, n = _pad_flat(levels, BLOCK)
    nb = lb.shape[0]
    out = pl.pallas_call(
        functools.partial(_dequant_kernel, s=s),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, BLOCK), jnp.float32),
        interpret=interpret,
    )(lb, norm.reshape(1, 1))
    return out.reshape(-1)[:n].reshape(levels.shape)
