"""Jit'd public wrappers for the Pallas kernels.

On TPU the kernels compile through Mosaic; on this CPU container they run
in interpret mode (the kernel body executed in python) so the whole system
works everywhere.  The model code calls these wrappers, never pallas_call
directly."""
from __future__ import annotations

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import param_variance as _pv
from repro.kernels import qsgd_quant as _qq


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=_interpret())


def qsgd_quantize(x, u, *, bits: int = 8):
    return _qq.quantize(x, u, bits=bits, interpret=_interpret())


def qsgd_dequantize(levels, norm, *, bits: int = 8):
    return _qq.dequantize(levels, norm, bits=bits, interpret=_interpret())


def param_mean_and_sqdev(w):
    return _pv.mean_and_sqdev(w, interpret=_interpret())
