"""Blockwise (flash) attention Pallas kernel for TPU.

Targets the MXU: 128-aligned q/k tiles live in VMEM, the softmax runs
online over k-blocks (the TPU grid's last dimension iterates sequentially,
so running max / normalizer / accumulator persist in VMEM scratch across
k-blocks).  Causal and sliding-window masks are applied from global block
indices.  Validated on CPU with interpret=True against ref.attention_ref.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  sm_scale: float, causal: bool, window: int,
                  block_q: int, block_k: int):
    i = pl.program_id(1)          # q block
    j = pl.program_id(2)          # k block (sequential innermost)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                 # (bq, d)
    k = k_ref[0].astype(jnp.float32)                 # (bk, d)
    v = v_ref[0].astype(jnp.float32)                 # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale

    q_pos = i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                              # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                           # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[0, :, :] = (acc_scr[...] /
                          jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q: (B,S,H,d); k,v: (B,S,K,d) with H % K == 0 (GQA: kv heads are
    repeated outside the kernel cheaply via index math on the BH grid)."""
    B, Sq, H, d = q.shape
    _, Sk, K, _ = k.shape
    G = H // K
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, d)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, Sk, d)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, Sk, d)

    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, Sk, bq, bk)
    grid = (B * H, Sq // bq, Sk // bk)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, sm_scale=1.0 / math.sqrt(d),
                          causal=causal, window=window,
                          block_q=bq, block_k=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq, d).transpose(0, 2, 1, 3)
