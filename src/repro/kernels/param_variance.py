"""Fused replica-mean + variance-probe Pallas kernel.

Algorithm 2 line 10–11 needs, at every sync, both the replica mean of every
parameter buffer and S_k = (1/n)·Σ_i ||w̄ − w_i||².  A naive implementation
reads each buffer twice (once for the mean, once for the deviations); this
kernel fuses both into one pass: each VMEM tile (R, BLOCK) produces its mean
slice and accumulates its squared-deviation partial into a scalar."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024


def _mean_sqdev_kernel(w_ref, mean_ref, sq_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        sq_ref[...] = jnp.zeros_like(sq_ref)

    w = w_ref[...].astype(jnp.float32)            # (R, BLOCK)
    mean = jnp.mean(w, axis=0, keepdims=True)     # (1, BLOCK)
    mean_ref[...] = mean.astype(mean_ref.dtype)
    dev = w - mean
    sq_ref[0, 0] += jnp.sum(dev * dev)


@functools.partial(jax.jit, static_argnames=("interpret",))
def mean_and_sqdev(w: jnp.ndarray, *, interpret: bool = False):
    """w: (R, ...) one stacked-replica buffer.  Returns (mean of shape
    w.shape[1:], Σ_i ||mean − w_i||² scalar f32).  Divide the scalar by R
    for the paper's S_k contribution."""
    R = w.shape[0]
    inner = w.shape[1:]
    flat = w.reshape(R, -1)
    n = flat.shape[1]
    pad = (-n) % BLOCK
    if pad:
        flat = jnp.concatenate(
            [flat, jnp.zeros((R, pad), flat.dtype)], axis=1)
    nb = flat.shape[1] // BLOCK
    mean, sq = pl.pallas_call(
        _mean_sqdev_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((R, BLOCK), lambda i: (0, i))],
        out_specs=[
            pl.BlockSpec((1, BLOCK), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, flat.shape[1]), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(flat)
    mean = mean[0, :n].reshape(inner)
    return mean, sq[0, 0]
