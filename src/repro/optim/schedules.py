"""Learning-rate schedules.

``step``   — the paper's schedule (×0.1 at given steps; CIFAR: epochs 80/120).
``wsd``    — Warmup-Stable-Decay (MiniCPM, arXiv:2404.06395).
``cosine`` — standard cosine with warmup.
"""
from __future__ import annotations

import math
from typing import Callable, Sequence


def make_lr_schedule(kind: str, base_lr: float, total_steps: int, *,
                     warmup_steps: int = 0,
                     decay_steps: Sequence[int] = (),
                     decay_factor: float = 0.1,
                     final_frac: float = 0.1,
                     decay_frac: float = 0.1) -> Callable[[int], float]:
    """Returns a host-side python function step -> lr (the controller needs
    gamma_k on the host for Algorithm 2, so schedules are plain python)."""

    def warmup(k: int) -> float:
        if warmup_steps and k < warmup_steps:
            return base_lr * (k + 1) / warmup_steps
        return -1.0

    if kind == "constant":
        def f(k):
            w = warmup(k)
            return w if w >= 0 else base_lr
    elif kind == "step":
        def f(k):
            w = warmup(k)
            if w >= 0:
                return w
            lr = base_lr
            for s in decay_steps:
                if k >= s:
                    lr *= decay_factor
            return lr
    elif kind == "cosine":
        def f(k):
            w = warmup(k)
            if w >= 0:
                return w
            t = (k - warmup_steps) / max(1, total_steps - warmup_steps)
            return base_lr * (final_frac + (1 - final_frac)
                              * 0.5 * (1 + math.cos(math.pi * min(t, 1.0))))
    elif kind == "wsd":
        decay_start = int(total_steps * (1 - decay_frac))

        def f(k):
            w = warmup(k)
            if w >= 0:
                return w
            if k < decay_start:
                return base_lr
            t = (k - decay_start) / max(1, total_steps - decay_start)
            return base_lr * (final_frac ** min(t, 1.0))
    else:
        raise ValueError(kind)
    return f
