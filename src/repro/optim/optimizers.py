"""Optimizers as pure functions (no optax on this container — built in JAX).

An ``Optimizer`` is a pair of pure functions so it vmaps cleanly over the
replica axis used by the periodic-averaging algorithms:

    state               = opt.init(params)
    params, state       = opt.update(grads, state, params, lr)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jnp.ndarray], Tuple[Any, Any]]


def sgd(weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {}

    def update(grads, state, params, lr):
        def upd(p, g):
            g = g + weight_decay * p if weight_decay else g
            return p - lr * g.astype(p.dtype)
        return jax.tree_util.tree_map(upd, params, grads), state

    return Optimizer("sgd", init, update)


def momentum(beta: float = 0.9, weight_decay: float = 0.0,
             nesterov: bool = False) -> Optimizer:
    """Heavy-ball momentum — the paper's optimizer (coef 0.9, §IV-A)."""

    def init(params):
        return {"m": jax.tree_util.tree_map(jnp.zeros_like, params)}

    def update(grads, state, params, lr):
        def upd_m(m, g, p):
            g = g + weight_decay * p if weight_decay else g
            return beta * m + g
        m = jax.tree_util.tree_map(upd_m, state["m"], grads, params)
        if nesterov:
            def upd_p(p, m_, g):
                return p - lr * (beta * m_ + g).astype(p.dtype)
            new_params = jax.tree_util.tree_map(upd_p, params, m, grads)
        else:
            new_params = jax.tree_util.tree_map(
                lambda p, m_: p - lr * m_.astype(p.dtype), params, m)
        return new_params, {"m": m}

    return Optimizer("momentum", init, update)


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        z = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, z),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        t = state["t"] + 1
        m = jax.tree_util.tree_map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads)
        v = jax.tree_util.tree_map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(p, m_, v_):
            step = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            return (p - lr * (step + weight_decay * p.astype(jnp.float32))
                    .astype(p.dtype))
        return (jax.tree_util.tree_map(upd, params, m, v),
                {"m": m, "v": v, "t": t})

    return Optimizer("adamw", init, update)


def get_optimizer(name: str, *, momentum_coef: float = 0.9,
                  weight_decay: float = 0.0) -> Optimizer:
    if name == "sgd":
        return sgd(weight_decay)
    if name == "momentum":
        return momentum(momentum_coef, weight_decay)
    if name == "adamw":
        return adamw(weight_decay=weight_decay or 0.1)
    raise ValueError(name)
