from repro.optim.optimizers import (  # noqa: F401
    get_optimizer, sgd, momentum, adamw, Optimizer,
)
from repro.optim.schedules import make_lr_schedule  # noqa: F401
