"""Configuration system for the ADPSGD reproduction framework.

Every assigned architecture is expressed as a ``ModelConfig`` (architecture
hyper-parameters), a ``ParallelismPlan`` (how it maps onto the production
mesh) and an ``AveragingConfig`` (the paper's technique — Algorithm 2
hyper-parameters).  Configs are plain frozen dataclasses so they hash and
can key jit caches.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration (GShard-style grouped dispatch)."""

    n_experts: int = 8
    top_k: int = 2
    d_ff_expert: int = 0          # expert hidden width
    n_shared_experts: int = 0     # DeepSeek-style always-on shared experts
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    router_z_coef: float = 1e-3
    first_k_dense: int = 0        # first k layers use a dense MLP instead
    d_ff_dense: int = 0           # width of those dense layers (0 -> d_ff_expert)
    moe_every: int = 1            # apply MoE every k-th layer (1 = every layer)


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0              # 0 -> ceil(d_model / 16)


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 0          # 0 -> direct q projection (V2-Lite)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder tower for enc-dec models (whisper).  The modality frontend
    (mel spectrogram + conv subsampling) is stubbed: ``input_specs`` feeds
    post-frontend frame embeddings of shape (B, n_frames, d_model)."""

    n_layers: int = 24
    n_heads: int = 16
    n_frames: int = 1500          # whisper: 30 s of audio @ 2x conv stride


@dataclass(frozen=True)
class VisionStubConfig:
    """VLM vision frontend stub: ``input_specs`` feeds patch embeddings
    (B, n_patches, d_model) which are prepended to the token sequence."""

    n_patches: int = 64
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # t/h/w split of dh/2


# ---------------------------------------------------------------------------
# ModelConfig
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"         # dense | moe | ssm | hybrid | vlm | audio
    source: str = ""              # citation of the config's source

    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_head: int = 0               # 0 -> d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 32000
    max_seq_len: int = 4096

    # --- norm / activation ---
    norm_type: str = "rmsnorm"    # rmsnorm | layernorm | nonparametric_ln
    norm_eps: float = 1e-5
    mlp_type: str = "swiglu"      # swiglu | gelu
    tie_embeddings: bool = False

    # --- attention ---
    attention_type: str = "gqa"   # gqa | mla
    attn_qkv_bias: bool = False
    pos_type: str = "rope"        # rope | mrope | sinusoidal | learned | none
    rope_theta: float = 10000.0
    partial_rotary_factor: float = 1.0
    sliding_window: int = 0       # 0 = full attention; >0 = SWA window
    attn_logit_softcap: float = 0.0

    # --- scaling tricks (minicpm / mup-style) ---
    emb_scale: float = 1.0
    residual_scale: float = 1.0
    logit_scale: float = 1.0

    # --- block pattern ---
    # None -> all "attn".  Otherwise a repeating pattern over layers, e.g.
    # jamba: ("mamba","mamba","mamba","attn","mamba","mamba","mamba","mamba")
    # xlstm: ("mlstm","mlstm","mlstm","mlstm","mlstm","mlstm","slstm")
    layer_pattern: Optional[Tuple[str, ...]] = None

    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    mla: Optional[MLAConfig] = None
    encoder: Optional[EncoderConfig] = None
    vision: Optional[VisionStubConfig] = None

    # --- numerics / compile ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    use_flash: bool = False       # Pallas flash attention (TPU); jnp path off-TPU
    remat: bool = True
    remat_policy: str = "nothing" # nothing(_saveable) | dots (dots_saveable)
    scan_layers: bool = True      # lax.scan over repeating layer groups
                                  # (compile time ~O(1) in depth; MaxText-style)
    act_dp_axis: str = ""         # constrain residual-stream batch dim to
                                  # this mesh axis (hillclimb A3: forces
                                  # GSPMD to keep compute batch-sharded)
    act_seq_axis: str = ""        # megatron sequence parallelism: shard the
                                  # residual seq dim over this axis between
                                  # sublayers (hillclimb C2)
    vocab_pad_multiple: int = 1   # pad embedding/vocab rows up to a multiple
                                  # (hillclimb D1: odd vocabs such as
                                  # minicpm's 122753 become shardable)

    # ------------------------------------------------------------------
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    def padded_vocab(self) -> int:
        m = max(1, self.vocab_pad_multiple)
        return ((self.vocab_size + m - 1) // m) * m

    def block_kind(self, layer_idx: int) -> str:
        if self.layer_pattern is None:
            return "attn"
        return self.layer_pattern[layer_idx % len(self.layer_pattern)]

    def layer_uses_moe(self, layer_idx: int) -> bool:
        m = self.moe
        if m is None:
            return False
        if layer_idx < m.first_k_dense:
            return False
        return (layer_idx % m.moe_every) == (m.moe_every - 1) if m.moe_every > 1 else True

    def scan_grouping(self) -> Optional[Tuple[int, int, int]]:
        """(prefix_len, period, n_groups) for lax.scan over layers, or None.
        Layers [prefix:] form n_groups repetitions of a `period`-long block
        pattern with identical parameter structure per slot."""
        if not self.scan_layers:
            return None
        import math as _math
        period = len(self.layer_pattern) if self.layer_pattern else 1
        if self.moe is not None:
            period = _math.lcm(period, max(1, self.moe.moe_every))
        prefix = self.moe.first_k_dense if self.moe else 0
        body = self.n_layers - prefix
        if body <= 0 or body % period or body // period < 2:
            return None
        return prefix, period, body // period

    def is_subquadratic(self) -> bool:
        """True if a 500k-token decode is feasible (bounded attention state)."""
        if self.layer_pattern is not None:
            kinds = set(self.layer_pattern)
            if kinds <= {"mamba", "mlstm", "slstm"}:
                return True
            # hybrid: attention layers must be sliding-window or rare-but-SWA;
            # jamba's attention is full but 1:7 — we allow it because the KV
            # cache is bounded by the few attention layers (documented).
            if "attn" in kinds and ("mamba" in kinds or "mlstm" in kinds):
                return True
        return self.sliding_window > 0

    def has_decoder(self) -> bool:
        return True  # all assigned archs have a decoder


# ---------------------------------------------------------------------------
# Parallelism / averaging / run configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParallelismPlan:
    """How an architecture maps onto the production mesh.

    plan = 'replica_dp' : parameters carry a leading replica axis sharded over
        the data axis (paper-faithful local-SGD workers; each worker is
        tensor-sharded over 'model').
    plan = 'fsdp'       : synchronous DP with parameter sharding over 'data'
        + tensor over 'model'; ADPSGD applies over the 'pod' axis when the
        mesh has one (DiLoCo-style hierarchical deployment).

    ``placement`` names how the execution backend lays replicas out
    (DESIGN.md §5): 'replica_ddp' keeps each replica a whole-model copy on
    its own replica-axis slot; 'replica_tp' lets one replica *span* the
    'model' mesh axis, sharding inner parameter dims with the megatron
    ``base_spec`` rules (partial-manual shard_map: manual over data/pod,
    'model' left to GSPMD).
    """

    plan: str = "replica_dp"      # replica_dp | fsdp | replica_ddp
    placement: str = "replica_ddp"  # replica_ddp | replica_tp
    shard_activations: bool = True
    remat_policy: str = "full"    # full | dots | none
    vocab_parallel_embed: bool = True   # megatron vocab-parallel embedding
                                        # (hillclimb #1; False = d-sharded)


@dataclass(frozen=True)
class AveragingConfig:
    """Paper technique hyper-parameters (Algorithm 2 + baselines)."""

    # any name registered in repro/strategies: adpsgd | cpsgd | fullsgd |
    # qsgd | decreasing | hier_adpsgd | qsgd_periodic | adacomm | dasgd | ...
    method: str = "adpsgd"
    p_init: int = 4               # initial averaging period
    p_const: int = 8              # CPSGD constant period
    k_sample_frac: float = 0.25   # K_s = frac * K  (paper: 0.25 CIFAR, 0.2 ImageNet)
    warmup_full_sync_steps: int = 0   # period-1 warmup (paper: first epoch)
    lower: float = 0.7            # S_k < lower * gamma * C2 -> p += 1
    upper: float = 1.3            # S_k > upper * gamma * C2 -> p -= 1
    p_min: int = 1
    p_max: int = 256
    sync_momentum: bool = False   # beyond-paper: average optimizer state too
    qsgd_bits: int = 8            # QSGD baseline quantization width
    # decreasing-period baseline of Wang & Joshi (paper §V-B shows harmful)
    decreasing_p0: int = 20
    decreasing_p1: int = 5
    # hierarchical (hier_adpsgd): in-pod sync period and replica-group size
    # (0 -> half the replicas form one group)
    inner_period: int = 1
    group_size: int = 0
    # AdaComm (Wang & Joshi, arXiv:1810.08313): refresh the period every
    # `adacomm_interval` steps as tau = ceil(p_init * sqrt(F_t / F_0)).
    # adacomm_mode='time' uses the paper's wall-clock form instead: blocks
    # of `adacomm_t0` *seconds* on the engine's telemetry clock, with
    # straggler rescaling (runtime/clock.py; controller AdaCommTimeController)
    adacomm_interval: int = 20
    adacomm_mode: str = "iterations"   # iterations | time
    adacomm_t0: float = 1.0            # seconds per adaptation block
    # DaSGD (arXiv:2006.00441): the averaged correction from a sync at step
    # k is applied at step k + dasgd_delay (overlap window)
    dasgd_delay: int = 2


@dataclass(frozen=True)
class InputShape:
    name: str = "train_4k"
    seq_len: int = 4096
    global_batch: int = 256
    kind: str = "train"           # train | prefill | decode


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig = field(default_factory=ModelConfig)
    parallelism: ParallelismPlan = field(default_factory=ParallelismPlan)
    averaging: AveragingConfig = field(default_factory=AveragingConfig)
    # optimizer
    optimizer: str = "momentum"   # sgd | momentum | adamw
    learning_rate: float = 0.1
    momentum: float = 0.9         # paper: 0.9
    weight_decay: float = 0.0
    lr_schedule: str = "step"     # step | cosine | wsd | constant
    lr_warmup_steps: int = 0
    lr_decay_steps: Tuple[int, ...] = ()
    lr_decay_factor: float = 0.1
    total_steps: int = 1000
    seed: int = 0

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Any] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> RunConfig:
    if name not in _REGISTRY:
        # late import so that `configs/<arch>.py` modules self-register
        import importlib
        mod = name.replace("-", "_").replace(".", "_")
        try:
            importlib.import_module(f"repro.configs.{mod}")
        except ImportError as exc:
            raise KeyError(
                f"unknown config '{name}'; available: {sorted(_REGISTRY)}"
            ) from exc
    return _REGISTRY[name]()


def available_configs() -> Sequence[str]:
    import importlib
    import pkgutil
    import repro.configs as pkg
    for m in pkgutil.iter_modules(pkg.__path__):
        if m.name not in ("base",):
            importlib.import_module(f"repro.configs.{m.name}")
    return sorted(_REGISTRY)


def reduced(model: ModelConfig, **overrides) -> ModelConfig:
    """Smoke-test variant: same family/block pattern, tiny dims."""
    changes: Dict[str, Any] = dict(
        n_layers=2,
        d_model=min(model.d_model, 128),
        n_heads=4,
        n_kv_heads=min(model.n_kv_heads, 4) or 4,
        d_head=32,
        d_ff=min(model.d_ff, 256) if model.d_ff else 0,
        vocab_size=min(model.vocab_size, 512),
        max_seq_len=256,
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
        scan_layers=False,
    )
    if model.moe is not None:
        changes["moe"] = dataclasses.replace(
            model.moe,
            n_experts=min(model.moe.n_experts, 4),
            top_k=min(model.moe.top_k, 2),
            d_ff_expert=64,
            d_ff_dense=64 if model.moe.d_ff_dense else 0,
            first_k_dense=min(model.moe.first_k_dense, 1),
        )
    if model.mla is not None:
        changes["mla"] = dataclasses.replace(
            model.mla, kv_lora_rank=64, qk_nope_head_dim=32,
            qk_rope_head_dim=16, v_head_dim=32)
        changes["d_head"] = 0
    if model.encoder is not None:
        changes["encoder"] = dataclasses.replace(
            model.encoder, n_layers=2, n_heads=4, n_frames=32)
    if model.vision is not None:
        changes["vision"] = dataclasses.replace(
            model.vision, n_patches=8, mrope_sections=(4, 6, 6))
    if model.layer_pattern is not None and len(model.layer_pattern) > 2:
        # keep one of each kind in a 2-layer smoke model
        kinds = list(dict.fromkeys(model.layer_pattern))
        changes["layer_pattern"] = tuple(kinds[:2]) if len(kinds) >= 2 else model.layer_pattern
    changes.update(overrides)
    return dataclasses.replace(model, **changes)
