"""GLM4-9B [dense] — RoPE (partial, 0.5), extreme GQA kv=2 [hf:THUDM/glm-4-9b]."""
from repro.configs.base import ModelConfig, ParallelismPlan, RunConfig, register


@register("glm4-9b")
def cfg() -> RunConfig:
    return RunConfig(
        model=ModelConfig(
            name="glm4-9b",
            family="dense",
            source="hf:THUDM/glm-4-9b",
            n_layers=40,
            d_model=4096,
            n_heads=32,
            n_kv_heads=2,
            d_ff=13696,
            vocab_size=151552,
            max_seq_len=131072,
            norm_type="rmsnorm",
            mlp_type="swiglu",
            attn_qkv_bias=True,       # GLM-4 uses qkv bias
            pos_type="rope",
            partial_rotary_factor=0.5,
            rope_theta=10000.0,
        ),
        parallelism=ParallelismPlan(plan="replica_dp"),
        optimizer="momentum",
        learning_rate=0.1,
        lr_schedule="step",
    )
