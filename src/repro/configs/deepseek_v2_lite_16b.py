"""DeepSeek-V2-Lite-16B [moe] — MLA kv_lora=512, 2 shared + 64 routed
experts top-6, first layer dense [arXiv:2405.04434].

MLA caches only the 512-dim latent + 64-dim shared rope key per token —
the decode-memory win this config demonstrates in §Roofline."""
from repro.configs.base import (MLAConfig, ModelConfig, MoEConfig,
                                ParallelismPlan, RunConfig, register)


@register("deepseek-v2-lite-16b")
def cfg() -> RunConfig:
    return RunConfig(
        model=ModelConfig(
            name="deepseek-v2-lite-16b",
            family="moe",
            source="arXiv:2405.04434",
            n_layers=27,
            d_model=2048,
            n_heads=16,
            n_kv_heads=16,
            d_ff=10944,               # dense first layer width
            vocab_size=102400,
            max_seq_len=32768,
            norm_type="rmsnorm",
            mlp_type="swiglu",
            pos_type="rope",
            rope_theta=10000.0,
            attention_type="mla",
            mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0,
                          qk_nope_head_dim=128, qk_rope_head_dim=64,
                          v_head_dim=128),
            moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408,
                          n_shared_experts=2, first_k_dense=1,
                          d_ff_dense=10944),
        ),
        parallelism=ParallelismPlan(plan="replica_dp"),
        optimizer="adamw",
        learning_rate=4e-4,
        lr_schedule="cosine",
    )
