from repro.configs.base import (  # noqa: F401
    AveragingConfig, InputShape, INPUT_SHAPES, MLAConfig, MambaConfig,
    ModelConfig, MoEConfig, ParallelismPlan, RunConfig, available_configs,
    get_config, reduced, register,
)
