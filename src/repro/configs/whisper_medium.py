"""Whisper-medium [audio] — enc-dec, conv frontend (stub) [arXiv:2212.04356].

Decoder tower per the assignment (24L, d=1024, 16H MHA, d_ff=4096, GELU,
LayerNorm, learned positions); 24-layer encoder over stubbed post-conv
frame embeddings (1500 frames = 30 s).  Cross-attention in every decoder
layer."""
from repro.configs.base import (EncoderConfig, ModelConfig, ParallelismPlan,
                                RunConfig, register)


@register("whisper-medium")
def cfg() -> RunConfig:
    return RunConfig(
        model=ModelConfig(
            name="whisper-medium",
            family="audio",
            source="arXiv:2212.04356",
            n_layers=24,
            d_model=1024,
            n_heads=16,
            n_kv_heads=16,
            d_ff=4096,
            vocab_size=51865,
            max_seq_len=32768,
            norm_type="layernorm",
            mlp_type="gelu",
            pos_type="learned",
            encoder=EncoderConfig(n_layers=24, n_heads=16, n_frames=1500),
            tie_embeddings=True,       # whisper ties decoder embed / head
        ),
        parallelism=ParallelismPlan(plan="replica_dp"),
        optimizer="adamw",
        learning_rate=1e-3,
        lr_schedule="cosine",
    )
