"""Jamba-1.5-Large-398B [hybrid] — Mamba+attention 1:7 interleave, MoE 16e
top-2 [arXiv:2403.19887].

Each 8-layer period has one attention layer (index 4, per the Jamba paper)
and seven Mamba layers; MoE replaces the MLP on every second layer.
398B total params => fsdp plan (ADPSGD across pods on the multi-pod mesh).
Hybrid SSM + rare attention bounds decode state => long_500k runs."""
from repro.configs.base import (MambaConfig, ModelConfig, MoEConfig,
                                ParallelismPlan, RunConfig, register)


@register("jamba-1.5-large-398b")
def cfg() -> RunConfig:
    return RunConfig(
        model=ModelConfig(
            name="jamba-1.5-large-398b",
            family="hybrid",
            source="arXiv:2403.19887",
            n_layers=72,
            d_model=8192,
            n_heads=64,
            n_kv_heads=8,
            d_head=128,
            d_ff=24576,
            vocab_size=65536,
            max_seq_len=524288,
            norm_type="rmsnorm",
            mlp_type="swiglu",
            pos_type="none",          # Jamba uses no positional encoding
            layer_pattern=("mamba", "mamba", "mamba", "mamba",
                           "attn", "mamba", "mamba", "mamba"),
            mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
            moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576,
                          moe_every=2),
        ),
        parallelism=ParallelismPlan(plan="fsdp"),
        optimizer="adamw",
        learning_rate=2e-4,
        lr_schedule="cosine",
    )
