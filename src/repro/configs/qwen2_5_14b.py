"""Qwen2.5-14B [dense] — GQA, QKV bias [hf:Qwen/Qwen2.5-0.5B family]."""
from repro.configs.base import ModelConfig, ParallelismPlan, RunConfig, register


@register("qwen2.5-14b")
def cfg() -> RunConfig:
    return RunConfig(
        model=ModelConfig(
            name="qwen2.5-14b",
            family="dense",
            source="hf:Qwen/Qwen2.5-0.5B",
            n_layers=48,
            d_model=5120,
            n_heads=40,
            n_kv_heads=8,
            d_head=128,
            d_ff=13824,
            vocab_size=152064,
            max_seq_len=32768,
            norm_type="rmsnorm",
            mlp_type="swiglu",
            attn_qkv_bias=True,
            pos_type="rope",
            rope_theta=1e6,
        ),
        parallelism=ParallelismPlan(plan="replica_dp"),
        optimizer="momentum",
        learning_rate=0.1,
        lr_schedule="step",
    )
