"""OLMo-1B [dense] — non-parametric LayerNorm [arXiv:2402.00838]."""
from repro.configs.base import ModelConfig, ParallelismPlan, RunConfig, register


@register("olmo-1b")
def cfg() -> RunConfig:
    return RunConfig(
        model=ModelConfig(
            name="olmo-1b",
            family="dense",
            source="arXiv:2402.00838",
            n_layers=16,
            d_model=2048,
            n_heads=16,
            n_kv_heads=16,
            d_ff=8192,
            vocab_size=50304,
            max_seq_len=4096,
            norm_type="nonparametric_ln",
            mlp_type="swiglu",
            pos_type="rope",
            rope_theta=10000.0,
            tie_embeddings=True,
        ),
        parallelism=ParallelismPlan(plan="replica_dp"),
        optimizer="adamw",
        learning_rate=4e-4,
        lr_schedule="cosine",
    )
