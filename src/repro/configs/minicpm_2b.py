"""MiniCPM-2B [dense] — WSD schedule, mup-style scaling (arch = llama-like)
[arXiv:2404.06395].

scale_emb=12, residual scale 1.4/sqrt(L), logits scaled by 1/(d/256) —
the MiniCPM tensor-program scalings."""
import math

from repro.configs.base import ModelConfig, ParallelismPlan, RunConfig, register


@register("minicpm-2b")
def cfg() -> RunConfig:
    n_layers = 40
    d_model = 2304
    return RunConfig(
        model=ModelConfig(
            name="minicpm-2b",
            family="dense",
            source="arXiv:2404.06395",
            n_layers=n_layers,
            d_model=d_model,
            n_heads=36,
            n_kv_heads=36,
            d_ff=5760,
            vocab_size=122753,
            max_seq_len=4096,
            norm_type="rmsnorm",
            mlp_type="swiglu",
            pos_type="rope",
            rope_theta=10000.0,
            emb_scale=12.0,
            residual_scale=1.4 / math.sqrt(n_layers),
            logit_scale=256.0 / d_model,
            tie_embeddings=True,
        ),
        parallelism=ParallelismPlan(plan="replica_dp"),
        optimizer="adamw",
        learning_rate=1e-2,
        lr_schedule="wsd",
        lr_warmup_steps=100,
    )
