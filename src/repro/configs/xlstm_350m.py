"""xLSTM-350M [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517].

xLSTM[7:1] block ratio (7 mLSTM per sLSTM, the paper's LM configuration);
d_ff=0 because both block types carry their own projections (mLSTM:
pre-up-projection x2; sLSTM: post-up-projection gated FFN).  Attention-free
=> the long_500k decode shape runs with O(1) state."""
from repro.configs.base import ModelConfig, ParallelismPlan, RunConfig, register


@register("xlstm-350m")
def cfg() -> RunConfig:
    return RunConfig(
        model=ModelConfig(
            name="xlstm-350m",
            family="ssm",
            source="arXiv:2405.04517",
            n_layers=24,
            d_model=1024,
            n_heads=4,
            n_kv_heads=4,
            d_ff=0,
            vocab_size=50304,
            max_seq_len=524288,
            norm_type="layernorm",
            pos_type="none",
            layer_pattern=("mlstm", "mlstm", "mlstm", "slstm",
                           "mlstm", "mlstm", "mlstm", "mlstm"),
            tie_embeddings=True,
        ),
        parallelism=ParallelismPlan(plan="replica_dp"),
        optimizer="adamw",
        learning_rate=1e-3,
        lr_schedule="cosine",
    )
