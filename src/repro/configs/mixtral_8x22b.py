"""Mixtral-8x22B [moe] — 8 experts top-2, sliding-window attention
[arXiv:2401.04088].

~141B total / ~39B active parameters: full 16-way replica_dp replication
exceeds HBM, so the parallelism plan is fsdp (params sharded over 'data',
experts/tensor over 'model'); ADPSGD applies across pods on the multi-pod
mesh (DESIGN.md §4).  Native SWA (window 4096) bounds the KV cache =>
long_500k runs."""
from repro.configs.base import (ModelConfig, MoEConfig, ParallelismPlan,
                                RunConfig, register)


@register("mixtral-8x22b")
def cfg() -> RunConfig:
    return RunConfig(
        model=ModelConfig(
            name="mixtral-8x22b",
            family="moe",
            source="arXiv:2401.04088",
            n_layers=56,
            d_model=6144,
            n_heads=48,
            n_kv_heads=8,
            d_head=128,
            d_ff=16384,
            vocab_size=32768,
            max_seq_len=65536,
            norm_type="rmsnorm",
            mlp_type="swiglu",
            pos_type="rope",
            rope_theta=1e6,
            sliding_window=4096,
            moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=16384),
        ),
        parallelism=ParallelismPlan(plan="fsdp"),
        optimizer="adamw",
        learning_rate=3e-4,
        lr_schedule="cosine",
    )
