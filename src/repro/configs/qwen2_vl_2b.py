"""Qwen2-VL-2B [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191].

Transformer backbone only; the ViT vision encoder + projector is stubbed:
``input_specs`` supplies pre-projected patch embeddings (B, n_patches, D)
prepended to the token sequence, and the 3D (temporal/height/width) M-RoPE
position ids.  head_dim 128 -> mrope sections (16,24,24) over dh/2 = 64
frequency slots (the Qwen2-VL split)."""
from repro.configs.base import (ModelConfig, ParallelismPlan, RunConfig,
                                VisionStubConfig, register)


@register("qwen2-vl-2b")
def cfg() -> RunConfig:
    return RunConfig(
        model=ModelConfig(
            name="qwen2-vl-2b",
            family="vlm",
            source="arXiv:2409.12191",
            n_layers=28,
            d_model=1536,
            n_heads=12,
            n_kv_heads=2,
            d_head=128,
            d_ff=8960,
            vocab_size=151936,
            max_seq_len=32768,
            norm_type="rmsnorm",
            mlp_type="swiglu",
            attn_qkv_bias=True,
            pos_type="mrope",
            rope_theta=1e6,
            vision=VisionStubConfig(n_patches=64, mrope_sections=(16, 24, 24)),
            tie_embeddings=True,       # 2B model ties embeddings
        ),
        parallelism=ParallelismPlan(plan="replica_dp"),
        optimizer="momentum",
        learning_rate=0.1,
        lr_schedule="step",
    )
