"""Abstract input specs (ShapeDtypeStruct stand-ins) + their shardings for
every (architecture x input shape) pair — no device allocation, weak-type
correct, shardable.  This is what the dry-run lowers against."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig

Pytree = Any


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ModelConfig, shape: InputShape, n_replicas: int,
                      plan: str = "replica_dp",
                      replica_axes: Tuple[str, ...] = None,
                      ) -> Tuple[Pytree, Pytree]:
    """Replica-stacked training batch: (specs, partition-specs).
    Batch layout: leaves carry (R, per_replica_batch, ...).  The leading dim
    shards over ``replica_axes`` (the mesh axes the plan assigns to
    replicas); within a replica group the batch shards over 'data' (fsdp)
    or 'model' (replica_ddp)."""
    R = n_replicas
    b = max(1, shape.global_batch // R)
    S = shape.seq_len
    if replica_axes is None:            # legacy heuristic
        replica_axes = ("pod", "data") if R > 16 else (
            ("data",) if R > 1 else ())
    rep_ax: Any = (None if not replica_axes else
                   (replica_axes if len(replica_axes) > 1 else replica_axes[0]))
    dp_ax = None
    if plan == "fsdp":
        dp_ax = "data"                  # sync-DP inside each pod group
    elif plan == "replica_ddp" and b % 16 == 0:
        dp_ax = "model"                 # DP-within-group hillclimb plan
    batch: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}
    if cfg.vision is not None:
        Pv = cfg.vision.n_patches
        St = S - Pv
        batch["tokens"] = _sds((R, b, St), jnp.int32)
        batch["vision_embeds"] = _sds((R, b, Pv, cfg.d_model), jnp.bfloat16)
        batch["mrope_pos"] = _sds((R, 3, b, S), jnp.int32)
        specs["tokens"] = P(rep_ax, dp_ax, None)
        specs["vision_embeds"] = P(rep_ax, dp_ax, None, None)
        specs["mrope_pos"] = P(rep_ax, None, dp_ax, None)
    else:
        batch["tokens"] = _sds((R, b, S), jnp.int32)
        specs["tokens"] = P(rep_ax, dp_ax, None)
    if cfg.encoder is not None:
        T = cfg.encoder.n_frames
        batch["frames"] = _sds((R, b, T, cfg.d_model), jnp.bfloat16)
        specs["frames"] = P(rep_ax, dp_ax, None, None)
    return batch, specs


def prefill_batch_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                        ) -> Tuple[Pytree, Pytree]:
    B, S = shape.global_batch, shape.seq_len
    d = dict(zip(mesh.axis_names, mesh.devices.shape)).get("data", 1)
    b_ax = "data" if B % d == 0 and B >= d else None
    batch: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}
    if cfg.vision is not None:
        Pv = cfg.vision.n_patches
        batch["tokens"] = _sds((B, S - Pv), jnp.int32)
        batch["vision_embeds"] = _sds((B, Pv, cfg.d_model), jnp.bfloat16)
        batch["mrope_pos"] = _sds((3, B, S), jnp.int32)
        specs["tokens"] = P(b_ax, None)
        specs["vision_embeds"] = P(b_ax, None, None)
        specs["mrope_pos"] = P(None, b_ax, None)
    else:
        batch["tokens"] = _sds((B, S), jnp.int32)
        specs["tokens"] = P(b_ax, None)
    if cfg.encoder is not None:
        T = cfg.encoder.n_frames
        batch["frames"] = _sds((B, T, cfg.d_model), jnp.bfloat16)
        specs["frames"] = P(b_ax, None, None)
    return batch, specs


def decode_batch_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                       ) -> Tuple[Pytree, Pytree]:
    B = shape.global_batch
    d = dict(zip(mesh.axis_names, mesh.devices.shape)).get("data", 1)
    b_ax = "data" if B % d == 0 and B >= d else None
    batch: Dict[str, Any] = {"tokens": _sds((B, 1), jnp.int32)}
    specs: Dict[str, Any] = {"tokens": P(b_ax, None)}
    if cfg.encoder is not None:
        T = cfg.encoder.n_frames
        batch["encoder_out"] = _sds((B, T, cfg.d_model), jnp.bfloat16)
        specs["encoder_out"] = P(b_ax, None, None)
    return batch, specs


def abstract_caches(cfg: ModelConfig, batch: int, max_len: int) -> Pytree:
    from repro.models import model as M
    return jax.eval_shape(
        lambda: M.init_caches(cfg, batch, max_len, dtype=jnp.bfloat16))


def abstract_params(cfg: ModelConfig, n_replicas: int = 0) -> Pytree:
    from repro.core.averaging import stack_replicas
    from repro.models import model as M

    def build():
        p = M.init_params(jax.random.PRNGKey(0), cfg)
        if n_replicas:
            p = stack_replicas(p, n_replicas)
        return p
    return jax.eval_shape(build)


def abstract_opt_state(opt, params_abs: Pytree, stacked: bool) -> Pytree:
    if stacked:
        return jax.eval_shape(lambda p: jax.vmap(opt.init)(p), params_abs)
    return jax.eval_shape(opt.init, params_abs)
