"""Step-function builders shared by the dry-run, the trainer and the server.

All functions are pure and jit-friendly; the caller supplies shardings at
jit time (dryrun.py / train.py).
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.core import averaging as avg
from repro.models import model as M
from repro.optim import get_optimizer

Pytree = Any


def make_loss_fn(cfg: ModelConfig):
    def loss_fn(params, batch):
        return M.lm_loss(params, batch, cfg)
    return loss_fn


def make_steps(run: RunConfig) -> Dict[str, Callable]:
    """Returns the three training programs of the paper's system:
       local_step — Algorithm 1/2 lines 3-4: zero replica-axis collectives
       sync_step  — parameter averaging + the S_k probe (one all-reduce)
       full_step  — FULLSGD baseline (gradient all-reduce every step)
    Each takes/returns replica-stacked (W, opt_state)."""
    cfg = run.model
    loss_fn = make_loss_fn(cfg)
    opt = get_optimizer(run.optimizer, momentum_coef=run.momentum,
                        weight_decay=run.weight_decay)
    local = avg.make_local_step(loss_fn, opt)
    full = avg.make_full_step(loss_fn, opt)

    def sync_step(W, opt_state):
        return avg.sync_replicas(
            W, opt_state, sync_momentum=run.averaging.sync_momentum)

    return {"local_step": local, "sync_step": sync_step, "full_step": full,
            "optimizer": opt, "loss_fn": loss_fn}


def make_prefill_step(cfg: ModelConfig):
    def prefill(params, batch):
        logits, _ = M.forward(params, batch, cfg)
        return logits[:, -1, :]
    return prefill


def make_serve_step(cfg: ModelConfig):
    def serve(params, batch, caches):
        logits, caches = M.decode_step(params, batch, caches, cfg)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1)
        return next_tok, caches
    return serve
