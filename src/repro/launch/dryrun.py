import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks device count on first init.
# The 512 placeholder host devices exist ONLY for the dry-run (multi-pod
# production mesh is 2x16x16); smoke tests and benches see 1 device.

"""Multi-pod dry-run: prove every (architecture x input-shape x mesh)
combination lowers + compiles on the production mesh, and extract the
roofline terms from the compiled artifact.

Per pair we lower the program the shape's kind dictates:
  train_4k     -> local_step + sync_step (the paper's two programs) and
                  full_step (FULLSGD baseline)
  prefill_32k  -> prefill_step
  decode_*     -> serve_step (one token against a full KV cache / SSM state)

Outputs one JSON record per (arch, shape, mesh, program) under
experiments/dryrun/, consumed by benchmarks/roofline.py and EXPERIMENTS.md.
"""
import argparse
import dataclasses
import json
import re
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_config
from repro.core.comm_model import roofline_terms
from repro.launch import sharding as sh
from repro.launch import specs as sp
from repro.launch import steps as st
from repro.launch.mesh import make_production_mesh, n_replicas_for, replica_axes_for
from repro.models import model as M

ARCHS = [
    "qwen2-vl-2b", "xlstm-350m", "whisper-medium", "qwen2.5-14b", "olmo-1b",
    "glm4-9b", "mixtral-8x22b", "jamba-1.5-large-398b",
    "deepseek-v2-lite-16b", "minicpm-2b",
]

# long_500k needs sub-quadratic attention (DESIGN.md §5)
LONG_OK = {"xlstm-350m", "jamba-1.5-large-398b", "mixtral-8x22b"}

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

_COLL = re.compile(
    r"=\s*(\w+)\[([\d,]*)\][^=]*?\b"
    r"(all-reduce-start|all-gather-start|reduce-scatter|all-to-all|"
    r"collective-permute-start|all-reduce|all-gather|collective-permute)\b")
_GROUPS = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS2 = re.compile(r"replica_groups=\{\{([^}]*)\}")

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "f8": 1}


def parse_collectives(hlo: str) -> Dict[str, Any]:
    """Sum per-chip collective traffic from post-SPMD HLO.  Shapes printed
    are per-partition; traffic factors per ring algorithm (DESIGN.md §7)."""
    by_type: Dict[str, float] = {}
    count: Dict[str, int] = {}
    for line in hlo.splitlines():
        mm = _COLL.search(line)
        if not mm:
            continue
        dtype, dims, op = mm.groups()
        op = op.replace("-start", "")
        if "-done" in line.split("=")[1][:40]:
            continue
        nbytes = _DTYPE_BYTES.get(dtype, _DTYPE_BYTES.get(dtype[:3], 4))
        size = 1
        if dims:
            for d in dims.split(","):
                size *= int(d)
        br = size * nbytes
        g = _GROUPS.search(line)
        if g:
            n = int(g.group(2))
        else:
            g2 = _GROUPS2.search(line)
            n = len(g2.group(1).split(",")) if g2 else 2
        if n <= 1:
            continue
        factor = {"all-reduce": 2.0 * (n - 1) / n,
                  "all-gather": (n - 1) / n,
                  "reduce-scatter": float(n - 1),
                  "all-to-all": (n - 1) / n,
                  "collective-permute": 1.0}[op]
        by_type[op] = by_type.get(op, 0.0) + br * factor
        count[op] = count.get(op, 0) + 1
    return {"bytes_by_type": by_type, "count_by_type": count,
            "total_bytes": sum(by_type.values())}


def analyze(compiled, n_chips: int) -> Dict[str, Any]:
    cost = compiled.cost_analysis()
    mem = compiled.memory_analysis()
    coll = parse_collectives(compiled.as_text())
    flops = float(cost.get("flops", 0.0))          # per-chip (post-SPMD)
    byts = float(cost.get("bytes accessed", 0.0))
    rec = {
        "flops_per_chip": flops,
        "hbm_bytes_per_chip": byts,
        "collective_bytes_per_chip": coll["total_bytes"],
        "collectives": coll,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": (mem.argument_size_in_bytes
                           + mem.temp_size_in_bytes),
        } if mem else None,
        "roofline": roofline_terms(
            flops * n_chips, byts * n_chips,
            coll["total_bytes"] * n_chips, n_chips),
    }
    return rec


def _lower_compile(fn, in_shardings, args, donate=()):
    t0 = time.time()
    jitted = jax.jit(fn, in_shardings=in_shardings,
                     donate_argnums=donate)
    lowered = jitted.lower(*args)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    return compiled, {"lower_s": t1 - t0, "compile_s": t2 - t1}


# ---------------------------------------------------------------------------
# Scan-aware cost extrapolation.
#
# XLA's HloCostAnalysis visits a while-loop body ONCE — a lax.scan over G
# layer groups under-counts flops/bytes/collectives by ~G.  The full
# (scanned) program is still compiled to prove lowering + memory; the cost
# terms are extrapolated EXACTLY from two small *unrolled* variants with
# prefix+P and prefix+2P layers: cost(L) is affine in L, so
#   cost(n_layers) = c1 + (c2 - c1) * (n_layers - L1) / (L2 - L1).
# Residual caveat (documented in EXPERIMENTS.md): recurrences that scan
# *within* a layer (sLSTM over time, mLSTM over chunks) remain under-counted
# in the compute term; the roofline table carries MODEL_FLOPS as the floor.
# ---------------------------------------------------------------------------


def _affine_extrapolate(a1: Dict, a2: Dict, L1: int, L2: int, L: int) -> Dict:
    t = (L - L1) / (L2 - L1)

    def ext(v1, v2):
        return v1 + (v2 - v1) * t

    out = {
        "flops_per_chip": ext(a1["flops_per_chip"], a2["flops_per_chip"]),
        "hbm_bytes_per_chip": ext(a1["hbm_bytes_per_chip"],
                                  a2["hbm_bytes_per_chip"]),
        "collective_bytes_per_chip": ext(a1["collective_bytes_per_chip"],
                                         a2["collective_bytes_per_chip"]),
    }
    by1 = a1["collectives"]["bytes_by_type"]
    by2 = a2["collectives"]["bytes_by_type"]
    out["collectives"] = {
        "bytes_by_type": {k: ext(by1.get(k, 0.0), by2.get(k, 0.0))
                          for k in set(by1) | set(by2)},
        "count_by_type": a2["collectives"]["count_by_type"],
        "total_bytes": out["collective_bytes_per_chip"],
    }
    return out


def _corrected_analysis(run, shape_kind: str, prog: str, mesh, n_chips: int,
                        R, rep_axes) -> Optional[Dict[str, Any]]:
    cfg = run.model
    g = cfg.scan_grouping()
    if g is None:
        return None
    prefix, P, G = g
    L1, L2 = prefix + P, prefix + 2 * P
    if L2 >= cfg.n_layers:
        return None
    small = []
    for L in (L1, L2):
        cfg_s = dataclasses.replace(cfg, n_layers=L, scan_layers=False)
        run_s = dataclasses.replace(run, model=cfg_s)
        compiled = _compile_program(run_s, shape_kind, prog, mesh, R, rep_axes)
        small.append(analyze(compiled, n_chips))
    return _affine_extrapolate(small[0], small[1], L1, L2, cfg.n_layers)


def _compile_program(run, shape_kind: str, prog: str, mesh, R, rep_axes):
    """Build + compile one program for (possibly layer-reduced) run."""
    cfg = run.model
    shape = _CURRENT_SHAPE[0]
    plan = run.parallelism
    if shape_kind == "train":
        fns = st.make_steps(run)
        W = sp.abstract_params(cfg, n_replicas=R)
        opt_abs = sp.abstract_opt_state(fns["optimizer"], W, stacked=True)
        pspec = sh.param_specs(cfg, W, mesh, plan, replica_axes=rep_axes,
                               stacked=True)
        ospec = sh.opt_specs(cfg, opt_abs, pspec, mesh, plan, rep_axes,
                             stacked=True)
        batch, bspec = sp.train_batch_specs(cfg, shape, R, plan.plan,
                                            replica_axes=rep_axes)
        lr = jax.ShapeDtypeStruct((), jnp.float32)
        in_sh = (sh.named(mesh, pspec), sh.named(mesh, ospec),
                 sh.named(mesh, bspec), NamedSharding(mesh, P()))
        if prog == "sync_step":
            c, _ = _lower_compile(fns["sync_step"], in_sh[:2], (W, opt_abs),
                                  donate=(0, 1))
        else:
            c, _ = _lower_compile(fns[prog], in_sh, (W, opt_abs, batch, lr),
                                  donate=(0, 1))
        return c
    if shape_kind == "prefill":
        prefill = st.make_prefill_step(cfg)
        params = sp.abstract_params(cfg)
        pspec = sh.param_specs(cfg, params, mesh, plan)
        batch, bspec = sp.prefill_batch_specs(cfg, shape, mesh)
        c, _ = _lower_compile(prefill, (sh.named(mesh, pspec),
                                        sh.named(mesh, bspec)),
                              (params, batch))
        return c
    raise ValueError(shape_kind)


_CURRENT_SHAPE = [None]


def run_pair(arch: str, shape_name: str, *, multi_pod: bool = False,
             programs: Optional[list] = None,
             run_override=None, correct: bool = True) -> Dict[str, Any]:
    run = run_override or get_config(arch)
    cfg = run.model
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    plan = run.parallelism
    rep_axes = replica_axes_for(plan.plan, multi_pod)
    R = n_replicas_for(mesh, plan.plan, multi_pod)
    record: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "plan": plan.plan, "n_replicas": R, "programs": {},
    }
    _CURRENT_SHAPE[0] = shape
    with mesh:
        if shape.kind == "train":
            progs = programs or ["local_step", "full_step", "sync_step"]
            for prog in progs:
                t0 = time.time()
                compiled = _compile_program(run, "train", prog, mesh, R,
                                            rep_axes)
                rec = analyze(compiled, n_chips)
                rec["compile_s"] = time.time() - t0
                if correct and prog != "sync_step":  # sync is exact
                    corr = _corrected_analysis(run, "train", prog, mesh,
                                               n_chips, R, rep_axes)
                else:
                    corr = None
                if corr is not None:
                    rec["raw_scanned"] = {
                        k: rec[k] for k in
                        ("flops_per_chip", "hbm_bytes_per_chip",
                         "collective_bytes_per_chip")}
                    rec.update(corr)
                    rec["roofline"] = roofline_terms(
                        corr["flops_per_chip"] * n_chips,
                        corr["hbm_bytes_per_chip"] * n_chips,
                        corr["collective_bytes_per_chip"] * n_chips,
                        n_chips)
                    rec["cost_corrected"] = True
                record["programs"][prog] = rec
        elif shape.kind == "prefill":
            t0 = time.time()
            compiled = _compile_program(run, "prefill", "prefill_step",
                                        mesh, R, rep_axes)
            rec = analyze(compiled, n_chips)
            rec["compile_s"] = time.time() - t0
            corr = _corrected_analysis(run, "prefill", "prefill_step", mesh,
                                       n_chips, R, rep_axes) if correct \
                else None
            if corr is not None:
                rec["raw_scanned"] = {
                    k: rec[k] for k in ("flops_per_chip",
                                        "hbm_bytes_per_chip",
                                        "collective_bytes_per_chip")}
                rec.update(corr)
                rec["roofline"] = roofline_terms(
                    corr["flops_per_chip"] * n_chips,
                    corr["hbm_bytes_per_chip"] * n_chips,
                    corr["collective_bytes_per_chip"] * n_chips, n_chips)
                rec["cost_corrected"] = True
            record["programs"]["prefill_step"] = rec
        else:  # decode — python-loop layers, cost exact
            serve = st.make_serve_step(cfg)
            params = sp.abstract_params(cfg)
            pspec = sh.param_specs(cfg, params, mesh, plan)
            batch, bspec = sp.decode_batch_specs(cfg, shape, mesh)
            caches = sp.abstract_caches(cfg, shape.global_batch, shape.seq_len)
            cspec = sh.cache_specs(cfg, caches, mesh, batch=shape.global_batch)
            in_sh = (sh.named(mesh, pspec), sh.named(mesh, bspec),
                     sh.named(mesh, cspec))
            compiled, t = _lower_compile(serve, in_sh, (params, batch, caches),
                                         donate=(2,))
            record["programs"]["serve_step"] = {
                **analyze(compiled, n_chips), **t}
    return record


def pair_is_runnable(arch: str, shape_name: str) -> bool:
    if shape_name == "long_500k" and arch not in LONG_OK:
        return False
    return True


def save_record(rec: Dict[str, Any]) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    mp = rec["mesh"]
    path = os.path.join(
        OUT_DIR, f"{rec['arch']}__{rec['shape']}__{mp}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--programs", default=None,
                    help="comma list, e.g. local_step,sync_step")
    ap.add_argument("--no-correction", action="store_true",
                    help="skip scan-cost anchor compiles (multi-pod sweep: "
                         "the roofline table is single-pod only)")
    args = ap.parse_args()
    pairs = []
    if args.all:
        for a in ARCHS:
            for s in INPUT_SHAPES:
                if pair_is_runnable(a, s):
                    pairs.append((a, s))
    else:
        assert args.arch and args.shape
        pairs = [(args.arch, args.shape)]
    progs = args.programs.split(",") if args.programs else None
    for a, s in pairs:
        t0 = time.time()
        try:
            rec = run_pair(a, s, multi_pod=args.multi_pod, programs=progs,
                           correct=not args.no_correction)
            path = save_record(rec)
            for pn, pr in rec["programs"].items():
                r = pr["roofline"]
                print(f"OK  {a:24s} {s:12s} {pn:12s} "
                      f"compute={r['compute_s']:.3e}s mem={r['memory_s']:.3e}s "
                      f"coll={r['collective_s']:.3e}s dom={r['dominant']:10s} "
                      f"[{time.time()-t0:.0f}s] -> {os.path.basename(path)}")
        except Exception as e:  # noqa: BLE001 — a failure IS the finding
            print(f"FAIL {a} {s}: {type(e).__name__}: {e}")
            raise


if __name__ == "__main__":
    main()
