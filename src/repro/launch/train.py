"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --method adpsgd \
        --steps 200 --replicas 4 --reduced --backend vmap

``--method`` accepts any name registered in ``repro/strategies`` (the five
paper methods plus hier_adpsgd, qsgd_periodic, adacomm, dasgd, and anything
a plugin registers); ``--backend`` any name in ``repro/backends`` (vmap =
host device; mesh = replica axis sharded over the devices jax sees —
on this container set ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
to give the mesh N host devices, on a real cluster the same driver takes
the production mesh from launch/mesh.py).  ``--placement replica_tp`` lets
one mesh replica span the 'model' mesh axis (megatron-style tensor
parallelism inside each replica — DESIGN.md §5 "Placements");
``--model-parallel`` sizes that axis on the host mesh.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.backends import available_backends, make_backend
from repro.checkpoint.io import save_checkpoint, strategy_state
from repro.configs import AveragingConfig, get_config, reduced
from repro.data.pipeline import SyntheticTokens
from repro.launch.steps import make_loss_fn
from repro.models import model as M
from repro.optim import get_optimizer, make_lr_schedule
from repro.runtime.clock import make_clock
from repro.runtime.engine import Checkpointer, PeriodicEval, TrainerEngine
from repro.strategies import available_strategies, make_strategy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--method", default="adpsgd",
                    choices=available_strategies())
    ap.add_argument("--backend", default="vmap",
                    choices=available_backends(),
                    help="execution backend: where replicas live and how "
                         "syncs lower (repro/backends)")
    ap.add_argument("--sync-kernel", default="auto",
                    choices=["auto", "on", "off"],
                    help="fused Pallas mean+sqdev kernel in the sync "
                         "(auto = on TPU only, where it is profitable)")
    ap.add_argument("--placement", default="replica_ddp",
                    choices=["replica_ddp", "replica_tp"],
                    help="mesh-backend replica layout: replica_ddp = each "
                         "replica is a whole-model copy; replica_tp = one "
                         "replica spans the 'model' mesh axis "
                         "(megatron-style TP inside each replica)")
    ap.add_argument("--model-parallel", type=int, default=0,
                    help="model-axis size of the host mesh (0 = auto: 2 "
                         "for replica_tp when the device count is even, "
                         "else 1)")
    ap.add_argument("--net", default="none",
                    help="telemetry clock (runtime/clock.py): 'none' = no "
                         "instrumentation, 'real' = WallClock around "
                         "block-until-ready dispatches, '10gbps'/'100gbps'/"
                         "'<x>gbps' = SimulatedClock charging compute per "
                         "step and communication from the analytic model "
                         "at that bandwidth (bit-reproducible)")
    ap.add_argument("--wallclock-sample-every", type=int, default=1,
                    help="with --net real: block-until-ready only every N "
                         "steps and interpolate the Timeline in between, "
                         "keeping the async dispatch pipeline N steps deep "
                         "(1 = measure every dispatch)")
    ap.add_argument("--adacomm-mode", default="iterations",
                    choices=["iterations", "time"],
                    help="adacomm block definition: 'iterations' (interval "
                         "of steps) or 'time' (t0-second wall-clock blocks "
                         "on the --net clock, the paper's form)")
    ap.add_argument("--adacomm-t0", type=float, default=1.0,
                    help="seconds per adacomm_mode=time adaptation block")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--replicas", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4, help="per-replica batch")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--p-init", type=int, default=2)
    ap.add_argument("--p-const", type=int, default=8)
    ap.add_argument("--warmup-sync", type=int, default=8)
    ap.add_argument("--inner-period", type=int, default=1)
    ap.add_argument("--lr", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default=None,
                    help="write a final checkpoint (replica-averaged) here")
    ap.add_argument("--out", default=None)
    # callback-bus flags: periodic eval + periodic (resumable) checkpoints
    ap.add_argument("--eval-every", type=int, default=0,
                    help="evaluate the replica-averaged model every N steps")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="checkpoint every N steps (needs --ckpt-path)")
    ap.add_argument("--ckpt-path", default=None,
                    help="directory for --ckpt-every checkpoints")
    ap.add_argument("--keep-replicas", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="periodic checkpoints keep the stacked replica "
                         "axis (resumable); --no-keep-replicas writes "
                         "replica-averaged export checkpoints")
    args = ap.parse_args()

    run = get_config(args.arch)
    cfg = reduced(run.model, max_seq_len=args.seq) if args.reduced else run.model
    avg_cfg = AveragingConfig(
        method=args.method, p_init=args.p_init, p_const=args.p_const,
        warmup_full_sync_steps=args.warmup_sync, k_sample_frac=0.25,
        inner_period=args.inner_period, adacomm_mode=args.adacomm_mode,
        adacomm_t0=args.adacomm_t0)
    clock = make_clock(args.net,
                       wallclock_sample_every=args.wallclock_sample_every)
    if args.adacomm_mode == "time" and clock is None:
        ap.error("--adacomm-mode time needs a clock: pass --net "
                 "real|10gbps|100gbps|<x>gbps")
    lr = args.lr if args.lr is not None else min(run.learning_rate, 0.05)
    lr_fn = make_lr_schedule(
        "step", lr, args.steps,
        decay_steps=(args.steps // 2, 3 * args.steps // 4))
    opt = get_optimizer(run.optimizer, momentum_coef=run.momentum)

    data = SyntheticTokens(cfg.vocab_size, args.seq,
                           n_samples=args.replicas * args.batch * 64,
                           seed=args.seed)
    data_fn = data.batches(n_replicas=args.replicas,
                           per_replica_batch=args.batch)
    params0 = M.init_params(jax.random.PRNGKey(args.seed), cfg)
    loss_fn = make_loss_fn(cfg)
    strategy = make_strategy(avg_cfg, args.steps)
    use_kernel = {"auto": None, "on": True, "off": False}[args.sync_kernel]
    backend_kw = dict(use_kernel=use_kernel)
    if args.backend == "mesh":
        backend_kw.update(placement=args.placement,
                          model_parallel=args.model_parallel or None)
    elif args.placement != "replica_ddp" or args.model_parallel:
        ap.error("--placement/--model-parallel are mesh-backend options "
                 "(use --backend mesh)")
    backend = make_backend(args.backend, **backend_kw)

    callbacks = []
    if args.eval_every:
        callbacks.append(PeriodicEval(
            loss_fn, lambda: data.eval_batches(batch=args.batch * 4),
            every=args.eval_every))
    if args.ckpt_every:
        if not args.ckpt_path:
            ap.error("--ckpt-every needs --ckpt-path")
        callbacks.append(Checkpointer(args.ckpt_path, every=args.ckpt_every,
                                      keep_replicas=args.keep_replicas))

    engine = TrainerEngine(
        loss_fn=loss_fn, optimizer=opt, params0=params0,
        n_replicas=args.replicas, data_fn=data_fn, lr_fn=lr_fn,
        avg_cfg=avg_cfg, total_steps=args.steps, strategy=strategy,
        backend=backend, clock=clock, callbacks=callbacks,
        track_variance_every=max(1, args.steps // 50), seed=args.seed)
    t0 = time.time()
    hist = engine.run()
    dt = time.time() - t0

    print(f"[{args.arch} / {args.method} / {args.backend}] "
          f"{args.steps} steps in {dt:.1f}s  ({backend.describe()})")
    print(f"  loss {hist.losses[0]:.4f} -> "
          f"{np.mean(hist.losses[-10:]):.4f}")
    print(f"  syncs={hist.n_syncs} mean_period="
          f"{args.steps / max(1, hist.n_syncs):.2f} "
          f"final_p={hist.period_history[-1] if hist.period_history else 1}")
    if hist.inner_sync_steps:
        print(f"  inner_syncs={len(hist.inner_sync_steps)}")
    if hist.evals:
        print(f"  evals={len(hist.evals)} last@step{hist.eval_steps[-1]}: "
              + " ".join(f"{k}={v:.4f}" for k, v in hist.evals[-1].items()))
    print(f"  weighted-avg Var[W_k] (paper Eq.9) = "
          f"{hist.weighted_avg_variance():.3e}")
    if hist.timing:
        t = hist.timing
        print(f"  [{t['clock']} clock / {args.net}] "
              f"compute={t['compute_s']:.3f}s comm={t['comm_s']:.3f}s "
              f"total={t['sim_wall_s']:.3f}s "
              f"bytes/node={t['bytes']:.3e}")
    if args.ckpt:
        from repro.core.averaging import replica_mean
        save_checkpoint(args.ckpt, replica_mean(hist.final_W),
                        step=args.steps,
                        controller_state=strategy_state(strategy))
        print(f"  checkpoint -> {args.ckpt}")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump({"arch": args.arch, "method": args.method,
                       "backend": args.backend,
                       "evals": hist.evals, "eval_steps": hist.eval_steps,
                       "losses": hist.losses, "s_k": hist.s_k,
                       "sync_steps": hist.sync_steps,
                       "periods": hist.period_history,
                       "inner_sync_steps": hist.inner_sync_steps,
                       "variances": hist.variances,
                       "variance_steps": hist.variance_steps,
                       "timing": hist.timing}, f)
        print(f"  history -> {args.out}")


if __name__ == "__main__":
    main()
