"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --method adpsgd \
        --steps 200 --replicas 4 --reduced

``--method`` accepts any name registered in ``repro/strategies`` (the five
paper methods plus hier_adpsgd, qsgd_periodic, and anything a plugin
registers).  On this container it runs reduced configs on the host device;
on a real cluster the same driver jits against ``make_production_mesh()``
with the shardings from launch/sharding.py (``--mesh prod``).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.checkpoint.io import save_checkpoint, strategy_state
from repro.configs import AveragingConfig, get_config, reduced
from repro.data.pipeline import SyntheticTokens
from repro.launch.steps import make_loss_fn
from repro.models import model as M
from repro.optim import get_optimizer, make_lr_schedule
from repro.runtime.engine import TrainerEngine
from repro.strategies import available_strategies, make_strategy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--method", default="adpsgd",
                    choices=available_strategies())
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--replicas", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4, help="per-replica batch")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--p-init", type=int, default=2)
    ap.add_argument("--p-const", type=int, default=8)
    ap.add_argument("--warmup-sync", type=int, default=8)
    ap.add_argument("--inner-period", type=int, default=1)
    ap.add_argument("--lr", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    run = get_config(args.arch)
    cfg = reduced(run.model, max_seq_len=args.seq) if args.reduced else run.model
    avg_cfg = AveragingConfig(
        method=args.method, p_init=args.p_init, p_const=args.p_const,
        warmup_full_sync_steps=args.warmup_sync, k_sample_frac=0.25,
        inner_period=args.inner_period)
    lr = args.lr if args.lr is not None else min(run.learning_rate, 0.05)
    lr_fn = make_lr_schedule(
        "step", lr, args.steps,
        decay_steps=(args.steps // 2, 3 * args.steps // 4))
    opt = get_optimizer(run.optimizer, momentum_coef=run.momentum)

    data = SyntheticTokens(cfg.vocab_size, args.seq,
                           n_samples=args.replicas * args.batch * 64,
                           seed=args.seed)
    data_fn = data.batches(n_replicas=args.replicas,
                           per_replica_batch=args.batch)
    params0 = M.init_params(jax.random.PRNGKey(args.seed), cfg)
    loss_fn = make_loss_fn(cfg)
    strategy = make_strategy(avg_cfg, args.steps)

    engine = TrainerEngine(
        loss_fn=loss_fn, optimizer=opt, params0=params0,
        n_replicas=args.replicas, data_fn=data_fn, lr_fn=lr_fn,
        avg_cfg=avg_cfg, total_steps=args.steps, strategy=strategy,
        track_variance_every=max(1, args.steps // 50), seed=args.seed)
    t0 = time.time()
    hist = engine.run()
    dt = time.time() - t0

    print(f"[{args.arch} / {args.method}] {args.steps} steps in {dt:.1f}s")
    print(f"  loss {hist.losses[0]:.4f} -> "
          f"{np.mean(hist.losses[-10:]):.4f}")
    print(f"  syncs={hist.n_syncs} mean_period="
          f"{args.steps / max(1, hist.n_syncs):.2f} "
          f"final_p={hist.period_history[-1] if hist.period_history else 1}")
    if hist.inner_sync_steps:
        print(f"  inner_syncs={len(hist.inner_sync_steps)}")
    print(f"  weighted-avg Var[W_k] (paper Eq.9) = "
          f"{hist.weighted_avg_variance():.3e}")
    if args.ckpt:
        from repro.core.averaging import replica_mean
        save_checkpoint(args.ckpt, replica_mean(hist.final_W),
                        step=args.steps,
                        controller_state=strategy_state(strategy))
        print(f"  checkpoint -> {args.ckpt}")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump({"arch": args.arch, "method": args.method,
                       "losses": hist.losses, "s_k": hist.s_k,
                       "sync_steps": hist.sync_steps,
                       "periods": hist.period_history,
                       "inner_sync_steps": hist.inner_sync_steps,
                       "variances": hist.variances,
                       "variance_steps": hist.variance_steps}, f)
        print(f"  history -> {args.out}")


if __name__ == "__main__":
    main()
