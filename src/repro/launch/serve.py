"""Serving driver: batched greedy decoding with KV caches / SSM states.

    PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --reduced \
        --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.launch.steps import make_serve_step
from repro.models import model as M
from repro.models import transformer as T


def generate(cfg, params, prompt: jnp.ndarray, gen_len: int,
             extra_batch=None, cache_len: int = 0):
    """Greedy decode: feeds the prompt token-by-token (prefill via decode
    path — correct for every state kind incl. SSM), then samples argmax."""
    B, S = prompt.shape
    caches = M.init_caches(cfg, B, cache_len or (S + gen_len),
                           dtype=jnp.float32)
    serve = jax.jit(make_serve_step(cfg))
    extra = extra_batch or {}
    tok = prompt[:, :1]
    out = [tok]
    nxt = None
    for t in range(S + gen_len - 1):
        nxt, caches = serve(params, {"tokens": tok, **extra}, caches)
        tok = prompt[:, t + 1:t + 2] if t + 1 < S else nxt[:, None]
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    run = get_config(args.arch)
    cfg = reduced(run.model) if args.reduced else run.model
    key = jax.random.PRNGKey(args.seed)
    params = M.init_params(key, cfg)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    extra = {}
    if cfg.encoder is not None:
        frames = jnp.zeros((args.batch, cfg.encoder.n_frames, cfg.d_model))
        extra["encoder_out"] = T.encoder_forward(
            params["encoder"], frames, cfg)
    t0 = time.time()
    out = generate(cfg, params, prompt, args.gen, extra_batch=extra)
    dt = time.time() - t0
    n_new = args.batch * args.gen
    print(f"[{args.arch}] generated {n_new} tokens in {dt:.1f}s "
          f"({n_new / dt:.1f} tok/s incl. compile)")
    print("sample:", np.asarray(out[0, -min(16, args.gen):]))
    assert out.shape == (args.batch, args.prompt_len + args.gen)
    assert int(out.max()) < cfg.vocab_size and int(out.min()) >= 0


if __name__ == "__main__":
    main()
