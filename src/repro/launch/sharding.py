"""Sharding rules: parameter pytree -> PartitionSpec pytree.

Rules are path-based (megatron-style tensor parallel over the ``model``
axis) with divisibility guards: a dim is sharded only if the mesh axis size
divides it, otherwise it stays replicated (GSPMD would reject the sharding
otherwise; the roofline then shows the cost, which is hillclimb material).

Plans (DESIGN.md §4):
  replica_dp — params gain a leading replica axis sharded over data (+pod);
  fsdp       — params additionally shard their largest replicated dim over
               ``data``; the replica axis (if any) maps to ``pod``.
"""
from __future__ import annotations

import re
from typing import Any, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelismPlan

Pytree = Any


def _axis_size(mesh: Mesh, name: str) -> int:
    # works for both Mesh and AbstractMesh
    return dict(mesh.shape).get(name, 1)


def _div(dim: int, size: int) -> bool:
    return size > 0 and dim % size == 0


# ---------------------------------------------------------------------------
# Base (unstacked, tensor-parallel) rules
# ---------------------------------------------------------------------------

# (path regex, callable(shape, msize) -> spec tuple over the param's own dims)
def _rules(cfg: ModelConfig, vocab_parallel: bool = True):
    def col(shape, m):      # shard last dim (output features)
        return (None,) * (len(shape) - 1) + ("model" if _div(shape[-1], m) else None,)

    def row(shape, m):      # shard first dim (input features)
        return ("model" if _div(shape[0], m) else None,) + (None,) * (len(shape) - 1)

    def expert(shape, m):   # (E, D, F): expert-parallel if E divides, else F
        if _div(shape[0], m):
            return ("model", None, None)
        if _div(shape[-1], m):
            return (None, None, "model")
        return (None, None, None)

    def expert_row(shape, m):  # (E, F, D)
        if _div(shape[0], m):
            return ("model", None, None)
        if _div(shape[1], m):
            return (None, "model", None)
        return (None, None, None)

    def rep(shape, m):
        return (None,) * len(shape)

    def emb(shape, m):
        # vocab-parallel embedding (megatron): with tied embeddings the LM
        # head contracts over d_model — vocab sharding keeps the (B,S,V)
        # logits sharded instead of all-reduced (hillclimb #1, EXPERIMENTS
        # §Perf).  Falls back to d_model sharding for odd vocab sizes.
        if vocab_parallel and _div(shape[0], m):
            return ("model", None)
        return (None, "model" if _div(shape[1], m) else None)

    return [
        (r"embed$", emb),
        (r"lm_head$", col),
        (r"\bwq\|w$|\bwk\|w$|\bwv\|w$", col),
        (r"\bwq\|b$|\bwk\|b$|\bwv\|b$", col),
        (r"\bwo\|w$", row),
        (r"wkv_a\|w$", rep),            # small latent projections (MLA)
        (r"wkv_b\|w$", col),
        (r"wq_a\|w$", rep),
        (r"w_gate\|w$|w_up\|w$|ff_gate$|ff_up$", col),
        (r"w_down\|w$|ff_down$", row),
        (r"moe\|router$", rep),
        (r"moe\|w_gate$|moe\|w_up$", expert),
        (r"moe\|w_down$", expert_row),
        (r"in_proj$|\bup$|\bwx$", col),
        (r"out_proj$|\bdown$", row),
        (r"x_proj$|A_log$|dt_proj_b$|\bD$", row),
        (r"dt_proj_w$", col),
        (r"conv_w$|conv_b$", col),
        (r"w_if$|b_i$|b_f$|ogate_norm$|\br$|\bgn$", rep),
        # compact CNN (models/cnn.py — the paper-faithful CIFAR stand-in):
        # conv output channels and fc1 columns shard over 'model', fc2 rows
        # contract over it — so placement tests/benches exercise real TP
        (r"convs\|#\d+\|[wb]$", col),
        (r"fc1\|[wb]$", col),
        (r"fc2\|w$", row),
        (r".*", rep),                   # norms, biases, scalars
    ]


def _path_str(path) -> str:
    parts = []
    for pp in path:
        if hasattr(pp, "key"):
            parts.append(str(pp.key))
        elif hasattr(pp, "idx"):
            parts.append(f"#{pp.idx}")
        else:
            parts.append(str(pp))
    return "|".join(parts)


def base_spec(cfg: ModelConfig, path_s: str, shape: Tuple[int, ...],
              mesh: Mesh, plan: ParallelismPlan) -> Tuple:
    m = _axis_size(mesh, "model")
    if plan.plan == "replica_ddp":
        # hillclimb plan: use the 'model' axis as extra data parallelism
        # inside each replica group (right for models too small to TP) —
        # params fully replicated, batch sharded over 'model'.
        return (None,) * len(shape)
    spec: Tuple = ()
    for pat, fn in _rules(cfg, getattr(plan, "vocab_parallel_embed", True)):
        if re.search(pat, path_s):
            spec = fn(shape, m)
            break
    if plan.plan == "fsdp":
        d = _axis_size(mesh, "data")
        # shard the largest still-replicated dim over 'data' (zero-3 style)
        order = sorted(range(len(shape)), key=lambda i: -shape[i])
        for i in order:
            if spec[i] is None and _div(shape[i], d) and shape[i] >= d:
                spec = spec[:i] + ("data",) + spec[i + 1:]
                break
    return spec


def _replica_spec_entry(replica_axes: Tuple[str, ...]):
    if not replica_axes:
        return None
    return replica_axes if len(replica_axes) > 1 else replica_axes[0]


def param_specs(cfg: ModelConfig, params_abs: Pytree, mesh: Mesh,
                plan: ParallelismPlan, *, replica_axes: Tuple[str, ...] = (),
                stacked: bool = False) -> Pytree:
    """PartitionSpec tree for (possibly replica-stacked) params.
    ``stacked``: leaves carry a leading replica dim (sharded over
    ``replica_axes``, e.g. ('data',) single-pod replica_dp, ('pod','data')
    multi-pod; replicated if replica_axes is empty)."""
    def one(path, x):
        ps = _path_str(path)
        shape = x.shape[1:] if stacked else x.shape
        spec = base_spec(cfg, ps, shape, mesh, plan)
        if stacked:
            spec = (_replica_spec_entry(replica_axes),) + spec
        return P(*spec)
    return jax.tree_util.tree_map_with_path(one, params_abs)


def opt_specs(cfg: ModelConfig, opt_abs: Pytree, param_spec_tree: Pytree,
              mesh: Mesh, plan: ParallelismPlan,
              replica_axes: Tuple[str, ...] = (),
              stacked: bool = False) -> Pytree:
    """Optimizer state mirrors parameter sharding (buffers have identical
    shapes); scalars (step counters) are replicated."""
    flat_params = {
        _path_str(p): s for p, s in
        jax.tree_util.tree_flatten_with_path(param_spec_tree)[0]}

    def one(path, x):
        ps = _path_str(path)
        # momentum trees have structure {m: <params-tree>}: strip the
        # leading state key and reuse the matching param's spec directly
        inner = ps.split("|", 1)[1] if "|" in ps else ps
        if inner in flat_params and flat_params[inner] is not None:
            return flat_params[inner]
        shape = x.shape[1:] if stacked else x.shape
        if len(shape) == 0:
            if stacked and x.ndim == 1:   # replicated step counter per lane
                return P(_replica_spec_entry(replica_axes))
            return P()
        spec = base_spec(cfg, ps, shape, mesh, plan)
        if stacked:
            spec = (_replica_spec_entry(replica_axes),) + spec
        return P(*spec)
    return jax.tree_util.tree_map_with_path(one, opt_abs)


def cache_specs(cfg: ModelConfig, caches_abs: Pytree, mesh: Mesh, *,
                batch: int) -> Pytree:
    """KV caches / SSM states for serving.  Batch dim shards over 'data'
    when divisible; otherwise (long-context B=1) the sequence dim shards
    over 'data' (flash-decoding style) and heads/channels over 'model'."""
    d = _axis_size(mesh, "data")
    m = _axis_size(mesh, "model")
    batch_shardable = _div(batch, d)

    def one(path, x):
        ps = _path_str(path)
        if x.ndim == 0 or ps.endswith("index"):
            return P()
        b_ax = "data" if batch_shardable else None
        if ps.endswith("|k") or ps.endswith("|v"):      # (B,S,K,dh)
            s_ax = None if batch_shardable else "data"
            if not _div(x.shape[1], d):
                s_ax = None
            h_ax = "model" if _div(x.shape[2], m) else None
            return P(b_ax, s_ax, h_ax, None)
        if ps.endswith("|pos"):                          # (B,S)
            s_ax = None if batch_shardable else ("data" if _div(x.shape[1], d) else None)
            return P(b_ax, s_ax)
        if ps.endswith("|ckv") or ps.endswith("|kpe"):   # (B,S,r) MLA latent
            s_ax = None if batch_shardable else ("data" if _div(x.shape[1], d) else None)
            return P(b_ax, s_ax, None)
        if ps.endswith("|ssm"):                          # (B,Di,N)
            return P(b_ax, "model" if _div(x.shape[1], m) else None, None)
        if ps.endswith("|conv"):                         # (B,K-1,Di)
            return P(b_ax, None, "model" if _div(x.shape[2], m) else None)
        if ps.endswith("|C"):                            # mlstm (B,H,dh,dh)
            return P(b_ax, "model" if _div(x.shape[1], m) else None, None, None)
        if ps.endswith("|n") or ps.endswith("|m"):       # (B,H,dh)/(B,H)
            h_ax = "model" if (x.ndim > 1 and _div(x.shape[1], m)) else None
            return P(*((b_ax, h_ax) + (None,) * (x.ndim - 2)))
        if x.ndim >= 2:                                  # slstm (B,D) etc.
            return P(b_ax, "model" if _div(x.shape[1], m) else None,
                     *(None,) * (x.ndim - 2))
        return P(b_ax)
    return jax.tree_util.tree_map_with_path(one, caches_abs)


def named(mesh: Mesh, spec_tree: Pytree) -> Pytree:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P))
