"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module touches no jax device state — required because the dry-run forces
512 host devices via XLA_FLAGS before first jax init, while tests and
benches must keep seeing 1 device.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: 256 chips as (data=16, model=16).
    Multi-pod: 2 pods = 512 chips as (pod=2, data=16, model=16)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1) -> Mesh:
    """Tiny mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))


def replica_axes_for(plan: str, multi_pod: bool):
    """Mesh axes consumed by the leading replica dim (DESIGN.md §4)."""
    if plan in ("replica_dp", "replica_ddp"):
        return ("pod", "data") if multi_pod else ("data",)
    # fsdp: local-SGD replicas only across pods (DiLoCo-style)
    return ("pod",) if multi_pod else ()


def n_replicas_for(mesh: Mesh, plan: str, multi_pod: bool) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    r = 1
    for ax in replica_axes_for(plan, multi_pod):
        r *= sizes.get(ax, 1)
    return max(r, 1)
