"""Host-device backend: all replicas on one device, programs via ``vmap``.

This is the PR-1 execution model, bit-exact: the replica axis is an ordinary
array dimension on the default device, the local step vmaps over it, and the
"collectives" are ``jnp.mean(axis=0)`` reductions.  It is the right backend
for single-accelerator runs and for CI, and the reference the mesh backend
is tested against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.backends.base import ExecutionBackend, register_backend
from repro.core import averaging as avg
from repro.core import qsgd as qsgd_mod


@register_backend
class VmapBackend(ExecutionBackend):
    """All replicas on the default device; ``vmap`` + ``jnp.mean``."""

    name = "vmap"

    # placement is the identity: the engine's stacked pytree already lives
    # where the programs run (put_* inherited as no-ops)

    def init_opt_state(self, optimizer, W):
        return jax.vmap(optimizer.init)(W)

    def describe(self):
        d = super().describe()
        d["use_kernel"] = self.use_kernel
        return d

    # ------------------------------------------------------------- programs
    # every builder returns through self.timed(...): with a bound clock each
    # invocation reports (compute_s, comm_s, bytes) into the Timeline, with
    # no clock the wrapper is pass-through (backends/base.py)
    def replica_step(self, loss_fn, optimizer):
        return self.timed(
            "replica_step", jax.jit(avg.make_local_step(loss_fn, optimizer)))

    def full_step(self, loss_fn, optimizer):
        return self.timed(
            "full_step", jax.jit(avg.make_full_step(loss_fn, optimizer)))

    def qsgd_step(self, loss_fn, optimizer, bits):
        return self.timed(
            "qsgd_step",
            jax.jit(qsgd_mod.make_qsgd_step(loss_fn, optimizer, bits)),
            bits=bits)

    def all_mean(self, *, sync_momentum: bool = False):
        use_kernel = self.use_kernel
        return self.timed("all_mean", jax.jit(lambda W, o: avg.sync_replicas(
            W, o, sync_momentum=sync_momentum, use_kernel=use_kernel)))

    def inner_mean(self, group_size: int):
        return self.timed("inner_mean",
                          jax.jit(lambda W: avg.group_sync(W, group_size)),
                          group_size=group_size)

    def opt_mean(self):
        return self.timed("opt_mean", jax.jit(avg.sync_opt_state))

    def quantized_all_mean(self, bits: int):
        """QSGD-quantized parameter deltas from a shared full-precision
        anchor; every replica adopts anchor + mean(dequantized deltas)."""

        @jax.jit
        def qsync(W, anchor, key):
            R = jax.tree_util.tree_leaves(W)[0].shape[0]
            delta = jax.tree_util.tree_map(
                lambda w, a: w.astype(jnp.float32) - a[None], W, anchor)
            keys = qsgd_mod.replica_keys(key, jnp.arange(R))
            dq = jax.vmap(
                lambda d, k: qsgd_mod.quantize_pytree(d, k, bits))(delta, keys)
            mean_d = jax.tree_util.tree_map(
                lambda d: jnp.mean(d, axis=0), dq)
            s_k = sum(
                jnp.sum(jnp.square(d - m[None])) / d.shape[0]
                for d, m in zip(jax.tree_util.tree_leaves(dq),
                                jax.tree_util.tree_leaves(mean_d)))
            new_anchor = jax.tree_util.tree_map(
                lambda a, m: a + m, anchor, mean_d)
            W_new = jax.tree_util.tree_map(
                lambda w, a: jnp.broadcast_to(a[None], w.shape).astype(w.dtype),
                W, new_anchor)
            return W_new, new_anchor, s_k

        return self.timed("quantized_all_mean", qsync, bits=bits)

    def mean_delta(self):
        @jax.jit
        def delta(W):
            means = jax.tree_util.tree_map(
                lambda x: jnp.mean(x.astype(jnp.float32), axis=0,
                                   keepdims=True), W)
            s_k = sum(
                jnp.sum(jnp.square(x.astype(jnp.float32) - m)) / x.shape[0]
                for x, m in zip(jax.tree_util.tree_leaves(W),
                                jax.tree_util.tree_leaves(means)))
            d = jax.tree_util.tree_map(
                lambda x, m: m - x.astype(jnp.float32), W, means)
            return d, s_k

        return self.timed("mean_delta", delta)
