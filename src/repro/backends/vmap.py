"""Host-device backend: all replicas on one device, programs via ``vmap``.

This is the PR-1 execution model, bit-exact: the replica axis is an ordinary
array dimension on the default device, the local step vmaps over it, and the
"collectives" are ``jnp.mean(axis=0)`` reductions.  It is the right backend
for single-accelerator runs and for CI, and the reference the mesh backend
is tested against.

Programs are ``_lower_<op>`` builders resolved by
``ExecutionBackend.lower(CollectiveOp)`` (``backends/ops.py``); pricing
derives from the op descriptor, never from the builder.  The quantized
exchange is **byte-true**: the payload is staged as int8 levels plus
per-tensor norms (``core/qsgd.quantize_split_pytree``, Pallas kernels on
TPU) and dequantized at the receiver — on one host device the "wire" is a
representation boundary, but it is the same levels+norms payload the mesh
backend all-gathers, so results match the sharded path bit-for-bit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.backends.base import ExecutionBackend, register_backend
from repro.core import averaging as avg
from repro.core import qsgd as qsgd_mod


@register_backend
class VmapBackend(ExecutionBackend):
    """All replicas on the default device; ``vmap`` + ``jnp.mean``."""

    name = "vmap"

    # placement is the identity: the engine's stacked pytree already lives
    # where the programs run (put_* inherited as no-ops)

    def init_opt_state(self, optimizer, W):
        return jax.vmap(optimizer.init)(W)

    def describe(self):
        d = super().describe()
        d["use_kernel"] = self.use_kernel
        return d

    # ------------------------------------------------------------ lowerings
    # resolved by ExecutionBackend.lower(op); every compiled program comes
    # back through timed(op, ...), so a bound clock prices each invocation
    # from the op descriptor (backends/base.py)
    def _lower_replica_step(self, op, *, loss_fn, optimizer):
        return jax.jit(avg.make_local_step(loss_fn, optimizer))

    def _lower_full_step(self, op, *, loss_fn, optimizer):
        return jax.jit(avg.make_full_step(loss_fn, optimizer))

    def _lower_qsgd_step(self, op, *, loss_fn, optimizer):
        return jax.jit(
            qsgd_mod.make_qsgd_step(loss_fn, optimizer, op.wire.bits))

    def _lower_all_mean(self, op, *, sync_momentum=False):
        use_kernel = self.use_kernel
        return jax.jit(lambda W, o: avg.sync_replicas(
            W, o, sync_momentum=sync_momentum, use_kernel=use_kernel))

    def _lower_inner_mean(self, op):
        g = op.group
        return jax.jit(lambda W: avg.group_sync(W, g))

    def _lower_opt_mean(self, op):
        return jax.jit(avg.sync_opt_state)

    def _lower_quantized_all_mean(self, op):
        """Byte-true QSGD-quantized parameter deltas from a shared
        full-precision anchor: each replica contributes (int8 levels,
        per-tensor norm); the receiver dequantizes and every replica adopts
        anchor + mean(dequantized deltas).  The quantize kernel routing is
        *platform*-keyed (TPU -> Pallas, else reference math), NOT
        ``use_kernel``-keyed: every backend must pick the same path or the
        exchange's cross-backend bit-match breaks on TPU (the kernel's
        blocked norm reduction rounds differently)."""
        bits = op.wire.bits
        use_kernel = jax.default_backend() == "tpu"

        @jax.jit
        def qsync(W, anchor, key):
            R = jax.tree_util.tree_leaves(W)[0].shape[0]
            delta = jax.tree_util.tree_map(
                lambda w, a: w.astype(jnp.float32) - a[None], W, anchor)
            keys = qsgd_mod.replica_keys(key, jnp.arange(R))
            levels, norms = jax.vmap(
                lambda d, k: qsgd_mod.quantize_split_pytree(
                    d, k, bits, use_kernel=use_kernel))(delta, keys)
            # the wire payload ends here; receiver-side dequantize
            dq = qsgd_mod.dequantize_split_pytree(levels, norms, bits)
            mean_d = jax.tree_util.tree_map(
                lambda d: jnp.mean(d, axis=0), dq)
            s_k = sum(
                jnp.sum(jnp.square(d - m[None])) / d.shape[0]
                for d, m in zip(jax.tree_util.tree_leaves(dq),
                                jax.tree_util.tree_leaves(mean_d)))
            new_anchor = jax.tree_util.tree_map(
                lambda a, m: a + m, anchor, mean_d)
            W_new = jax.tree_util.tree_map(
                lambda w, a: jnp.broadcast_to(a[None], w.shape).astype(w.dtype),
                W, new_anchor)
            return W_new, new_anchor, s_k

        return qsync

    def _lower_mean_delta(self, op):
        @jax.jit
        def delta(W):
            means = jax.tree_util.tree_map(
                lambda x: jnp.mean(x.astype(jnp.float32), axis=0,
                                   keepdims=True), W)
            s_k = sum(
                jnp.sum(jnp.square(x.astype(jnp.float32) - m)) / x.shape[0]
                for x, m in zip(jax.tree_util.tree_leaves(W),
                                jax.tree_util.tree_leaves(means)))
            d = jax.tree_util.tree_map(
                lambda x, m: m - x.astype(jnp.float32), W, means)
            return d, s_k

        return delta
