"""Multi-device backend: replica axis sharded over a real device mesh.

The replica axis of every stacked pytree is laid out over the mesh's
``data`` (and, multi-pod, ``pod``) axes using the stacked PartitionSpecs
from ``launch/sharding.py``; programs are built with ``shard_map`` so each
device advances its local replica chunk independently, and the strategy
syncs lower to real collectives — ``jax.lax.pmean``/``psum`` over the
replica mesh axes.  This is where the paper's communication savings become
physical: between syncs no *parameter* tensor ever crosses the replica
axes, and the local step's HLO carries **zero replica-axis collectives** —
per-replica scalar metrics (loss/grad-norm telemetry) come back stacked and
are reduced by a separate tiny program off the step path, so skipping a
sync genuinely skips every cross-replica round.

Two **placements** decide what one replica is (DESIGN.md §5):

* ``replica_ddp`` (default) — each replica is a whole-model copy; the
  leading replica axis is the only sharded dim and every program is a
  fully-manual ``shard_map`` over the replica axes.
* ``replica_tp``  — one replica *spans* the mesh's ``model`` axis: inner
  parameter dims shard with the megatron-style ``base_spec`` rules from
  ``launch/sharding.py`` (column/row-parallel matmuls, vocab-parallel
  embeddings), threaded through ``put_params``/``put_opt`` and pinned on
  program outputs.  Programs become *partial-manual* ``shard_map``s:
  manual over the replica axes (``data``/``pod``) so the replica-axis
  collectives stay explicit ``lax.pmean``/``psum``, while the ``model``
  axis is left to GSPMD (``auto={'model'}``), which inserts the
  intra-replica tensor-parallel collectives where the matmuls need them.

Cross-replica syncs are identical under both placements — the replica mean
is elementwise, so it never needs a model-axis exchange.  Checkpoints are
placement-neutral: ``device_get`` gathers to host arrays and the restoring
backend re-``put``s them under its own placement.

On this CPU container the mesh is whatever ``XLA_FLAGS=
--xla_force_host_platform_device_count=N`` provides (tests force 8, split
4 data x 2 model for ``replica_tp``); on a TPU pod the same code takes
``launch/mesh.py``'s production mesh.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.backends.base import ExecutionBackend, register_backend
from repro.configs.base import ModelConfig, ParallelismPlan
from repro.core import averaging as avg
from repro.core import qsgd as qsgd_mod
from repro.launch import mesh as mesh_mod
from repro.launch import sharding as shard_rules

Pytree = Any

_tm = jax.tree_util.tree_map
_leaves = jax.tree_util.tree_leaves

PLACEMENTS = ("replica_ddp", "replica_tp")


@register_backend
class MeshBackend(ExecutionBackend):
    """Replica axis over the mesh's ``data``/``pod`` axes, ``shard_map``
    programs, ``lax.pmean`` syncs; ``placement`` picks whole-copy replicas
    (``replica_ddp``) or model-axis-spanning ones (``replica_tp``)."""

    name = "mesh"

    def __init__(self, mesh: Optional[Mesh] = None, *,
                 model_cfg: Optional[ModelConfig] = None,
                 placement: str = "replica_ddp",
                 model_parallel: Optional[int] = None,
                 multi_pod: bool = False,
                 use_kernel: Optional[bool] = None):
        if use_kernel:
            # the fused mean+sqdev kernel is a per-device program over the
            # full replica axis; mesh syncs lower to pmean over chunks —
            # refuse rather than silently ignore --sync-kernel on
            raise NotImplementedError(
                "use_kernel is a VmapBackend option; MeshBackend lowers "
                "syncs to lax.pmean (use --sync-kernel auto/off with "
                "--backend mesh)")
        super().__init__(use_kernel=False)
        if placement not in PLACEMENTS:
            raise ValueError(
                f"unknown placement '{placement}'; available: {PLACEMENTS}")
        if mesh is None:
            if model_parallel is None:
                # replica_tp wants a nontrivial model axis when the device
                # count allows one; replica_ddp keeps every device a replica
                n = len(jax.devices())
                model_parallel = 2 if (placement == "replica_tp"
                                       and n > 1 and n % 2 == 0) else 1
            mesh = mesh_mod.make_host_mesh(model_parallel)
        self.mesh = mesh
        self.placement = placement
        sizes = dict(mesh.shape)
        self.replica_axes: Tuple[str, ...] = tuple(
            a for a in ("pod", "data") if a in mesh.axis_names)
        if not self.replica_axes:
            raise ValueError(
                f"mesh {mesh.axis_names} has no replica axis "
                "('data' or 'pod'); see launch/mesh.py")
        if placement == "replica_tp" and "model" not in mesh.axis_names:
            raise ValueError(
                f"placement 'replica_tp' needs a 'model' mesh axis, "
                f"got {mesh.axis_names}")
        self.n_replica_devices = int(
            np.prod([sizes[a] for a in self.replica_axes]))
        self._entry = (self.replica_axes if len(self.replica_axes) > 1
                       else self.replica_axes[0])
        self._model_cfg = model_cfg or ModelConfig()
        # replica_ddp: each replica is a full model copy, the replica axis
        # is the only sharded dim; replica_tp: inner dims additionally take
        # the megatron base_spec rules over 'model' (launch/sharding.py)
        self._plan = ParallelismPlan(
            plan="replica_dp" if placement == "replica_tp" else "replica_ddp",
            placement=placement)
        # partial-manual shard_map: manual over the replica axes, every
        # other mesh axis (the 'model' axis) left to GSPMD
        self._auto = (frozenset(set(mesh.axis_names) - set(self.replica_axes))
                      if placement == "replica_tp" else frozenset())
        self._cache: Dict[Any, Any] = {}
        self._ridx = None              # cached global replica-index array

    # ------------------------------------------------------------- topology
    def bind(self, n_replicas: int) -> None:
        if n_replicas % self.n_replica_devices:
            raise ValueError(
                f"n_replicas={n_replicas} not divisible by the mesh's "
                f"{self.n_replica_devices} replica devices "
                f"(axes {self.replica_axes} of {dict(self.mesh.shape)})")
        super().bind(n_replicas)

    def describe(self):
        return {"backend": self.name, "n_replicas": self.n_replicas,
                "n_devices": len(self.mesh.devices.reshape(-1)),
                "mesh": dict(self.mesh.shape),
                "placement": self.placement,
                "replica_axes": list(self.replica_axes)}

    def default_group_size(self) -> Optional[int]:
        """Replicas per pod, read off the mesh — the natural hierarchical
        group boundary (ROADMAP multi-pod item): inner syncs then ride the
        fast in-pod ICI and never the cross-pod link."""
        sizes = dict(self.mesh.shape)
        pods = sizes.get("pod", 1)
        if pods > 1 and self.n_replicas:
            return max(1, self.n_replicas // pods)
        return None

    # ------------------------------------------------------------ placement
    def _param_shardings(self, W: Pytree) -> Pytree:
        specs = shard_rules.param_specs(
            self._model_cfg, W, self.mesh, self._plan,
            replica_axes=self.replica_axes, stacked=True)
        return shard_rules.named(self.mesh, specs)

    def _opt_shardings(self, opt_state: Pytree, W: Pytree) -> Pytree:
        pspecs = shard_rules.param_specs(
            self._model_cfg, W, self.mesh, self._plan,
            replica_axes=self.replica_axes, stacked=True)
        ospecs = shard_rules.opt_specs(
            self._model_cfg, opt_state, pspecs, self.mesh, self._plan,
            replica_axes=self.replica_axes, stacked=True)
        return shard_rules.named(self.mesh, ospecs)

    def put_params(self, W: Pytree) -> Pytree:
        return jax.device_put(W, self._param_shardings(W))

    def put_opt(self, opt_state: Pytree, W: Pytree) -> Pytree:
        if not _leaves(opt_state):
            return opt_state
        return jax.device_put(opt_state, self._opt_shardings(opt_state, W))

    def put_replicated(self, tree: Pytree) -> Pytree:
        return jax.device_put(tree, NamedSharding(self.mesh, P()))

    def init_opt_state(self, optimizer, W: Pytree) -> Pytree:
        return self.put_opt(jax.vmap(optimizer.init)(W), W)

    # ----------------------------------------------------------- internals
    def _stacked(self, tree: Pytree) -> Pytree:
        """Per-leaf shard_map spec: leading replica dim over the replica
        axes.  Only the *manual* axes appear here — under ``replica_tp``
        the inner-dim 'model' sharding is GSPMD's (seeded by the operands'
        shardings, pinned on outputs via ``out_shardings``)."""
        return _tm(lambda x: P(self._entry), tree)

    def _replicated(self, tree: Pytree) -> Pytree:
        return _tm(lambda x: P(), tree)

    def _pin(self, *shardings):
        """jit ``out_shardings`` pinning the placement's parameter layout on
        program outputs (None = let GSPMD choose).  Only ``replica_tp``
        needs it — without the pin GSPMD tends to rematerialize outputs
        replicated over 'model', silently losing the TP layout.  Entries
        may be thunks so replica_ddp builds never pay the spec walk."""
        if self.placement != "replica_tp":
            return None
        return tuple(s() if callable(s) else s for s in shardings)

    def _cached(self, kind: str, trees, build):
        key = (kind, tuple(
            (jax.tree_util.tree_structure(t),
             tuple(np.shape(x) for x in _leaves(t)))
            for t in trees))
        fn = self._cache.get(key)
        if fn is None:
            fn = self._cache[key] = build()
        return fn

    def _shmap(self, chunk, in_specs, out_specs, out_shardings=None, *,
               auto=None):
        """``auto=None`` takes the placement's default (partial-manual with
        GSPMD owning 'model' under replica_tp); pass ``frozenset()`` to
        force a fully-manual region — required where the body carries an
        explicit gather collective, which XLA's partitioner rejects inside
        manual subgroups (same limitation family as PartitionId)."""
        fn = shard_map(chunk, mesh=self.mesh, in_specs=in_specs,
                       out_specs=out_specs, check_rep=False,
                       auto=self._auto if auto is None else auto)
        if out_shardings is not None:
            return jax.jit(fn, out_shardings=out_shardings)
        return jax.jit(fn)

    def _replica_index(self):
        """Global replica indices (R,), fed to RNG-bearing programs as a
        stacked operand — each chunk then sees its replicas' global ids.
        An explicit operand rather than ``lax.axis_index`` because the
        latter lowers to a PartitionId instruction that GSPMD rejects
        inside replica_tp's partial-manual (auto 'model') regions.
        Cached: qsgd_step rides the per-step hot path."""
        ridx = self._ridx
        if ridx is None or ridx.shape[0] != self.n_replicas:
            ridx = self._ridx = jnp.arange(self.n_replicas, dtype=jnp.int32)
        return ridx

    def _pmean(self, x):
        return jax.lax.pmean(x, self.replica_axes)

    def _leaf_mean(self, x):
        """Global replica mean of one stacked leaf chunk, keepdims —
        chunk means are equal-weight, so mean-of-chunk-means is exact."""
        return self._pmean(jnp.mean(x.astype(jnp.float32), axis=0,
                                    keepdims=True))

    def _probe(self, W_chunk, means):
        """S_k = (1/R) Σ_i ||w̄ − w_i||² from local partials + one psum.
        Under replica_tp the per-leaf sums run over model-sharded dims —
        GSPMD supplies the intra-replica reduction; the replica-axis psum
        stays the only manual collective."""
        s_loc = sum(jnp.sum(jnp.square(x.astype(jnp.float32) - m))
                    for x, m in zip(_leaves(W_chunk), _leaves(means)))
        return jax.lax.psum(s_loc, self.replica_axes) / self.n_replicas

    @staticmethod
    def _local_keys(key, ridx):
        """Per-replica RNG keys from the chunk's *global* replica indices —
        the shared ``qsgd.replica_keys`` stream, so it is independent of
        how replicas map to devices and matches VmapBackend bit-for-bit."""
        return qsgd_mod.replica_keys(key, ridx)

    def _metrics_mean(self, metrics: Pytree) -> Pytree:
        """Replica mean of stacked per-replica metrics — a separate tiny
        program, so the cross-replica round never rides the step's HLO
        (the engine reads the scalar back each iteration anyway)."""
        fn = self._cached("metrics_mean", (metrics,), lambda: jax.jit(
            lambda m: _tm(lambda x: jnp.mean(x, axis=0), m)))
        return fn(metrics)

    # ------------------------------------------------------------ lowerings
    # resolved by ExecutionBackend.lower(op) and wrapped by timed(op, ...):
    # with a bound clock each invocation is priced from the op descriptor
    # (backends/ops.py) — the builders only decide *how* the exchange runs
    def _lower_replica_step(self, op, *, loss_fn, optimizer):
        one_replica = avg.make_replica_step(loss_fn, optimizer)

        def chunk(Wc, oc, bc, lr):
            # per-chunk metrics stay stacked: the step program carries zero
            # replica-axis collectives (tested on its lowered HLO)
            return jax.vmap(one_replica, in_axes=(0, 0, 0, None))(
                Wc, oc, bc, lr)

        def prog(W, opt_state, batch, lr):
            fn = self._cached("step", (W, opt_state, batch), lambda: self._shmap(
                chunk,
                (self._stacked(W), self._stacked(opt_state),
                 self._stacked(batch), P()),
                (self._stacked(W), self._stacked(opt_state), P(self._entry)),
                out_shardings=self._pin(
                    lambda: self._param_shardings(W),
                    lambda: self._opt_shardings(opt_state, W), None)))
            W, opt_state, m = fn(W, opt_state, batch, lr)
            return W, opt_state, self._metrics_mean(m)

        return prog

    def _lower_full_step(self, op, *, loss_fn, optimizer):
        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

        def chunk(Wc, oc, bc, lr):
            (loss, aux), grads = jax.vmap(grad_fn)(Wc, bc)
            g_mean = _tm(self._leaf_mean, grads)
            g_bcast = _tm(lambda g, w: jnp.broadcast_to(g, w.shape), g_mean, Wc)
            Wn, on = jax.vmap(optimizer.update, in_axes=(0, 0, 0, None))(
                g_bcast, oc, Wc, lr)
            metrics = {"loss": self._pmean(jnp.mean(loss)),
                       **{k: self._pmean(jnp.mean(v)) for k, v in aux.items()}}
            return Wn, on, metrics

        def prog(W, opt_state, batch, lr):
            fn = self._cached("full", (W, opt_state, batch), lambda: self._shmap(
                chunk,
                (self._stacked(W), self._stacked(opt_state),
                 self._stacked(batch), P()),
                (self._stacked(W), self._stacked(opt_state), P()),
                out_shardings=self._pin(
                    lambda: self._param_shardings(W),
                    lambda: self._opt_shardings(opt_state, W), None)))
            return fn(W, opt_state, batch, lr)

        return prog

    def _lower_qsgd_step(self, op, *, loss_fn, optimizer):
        bits = op.wire.bits
        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

        def chunk(Wc, oc, bc, lr, key, ridx):
            (loss, aux), grads = jax.vmap(grad_fn)(Wc, bc)
            keys = self._local_keys(key, ridx)
            q = jax.vmap(lambda g, k: qsgd_mod.quantize_pytree(g, k, bits))(
                grads, keys)
            g_mean = _tm(self._leaf_mean, q)
            g_bcast = _tm(lambda g, w: jnp.broadcast_to(g, w.shape)
                          .astype(w.dtype), g_mean, Wc)
            Wn, on = jax.vmap(optimizer.update, in_axes=(0, 0, 0, None))(
                g_bcast, oc, Wc, lr)
            metrics = {"loss": self._pmean(jnp.mean(loss)),
                       **{k: self._pmean(jnp.mean(v)) for k, v in aux.items()}}
            return Wn, on, metrics

        def prog(W, opt_state, batch, lr, key):
            fn = self._cached("qsgd", (W, opt_state, batch), lambda: self._shmap(
                chunk,
                (self._stacked(W), self._stacked(opt_state),
                 self._stacked(batch), P(), P(), P(self._entry)),
                (self._stacked(W), self._stacked(opt_state), P()),
                out_shardings=self._pin(
                    lambda: self._param_shardings(W),
                    lambda: self._opt_shardings(opt_state, W), None)))
            return fn(W, opt_state, batch, lr, key, self._replica_index())

        return prog

    def _lower_all_mean(self, op, *, sync_momentum: bool = False):
        def chunk(Wc, oc):
            means = _tm(self._leaf_mean, Wc)
            s_k = self._probe(Wc, means)
            Wn = _tm(lambda x, m: jnp.broadcast_to(m, x.shape).astype(x.dtype),
                     Wc, means)
            if sync_momentum:
                oc = _tm(lambda x: jnp.broadcast_to(
                    self._leaf_mean(x), x.shape).astype(x.dtype), oc)
            return Wn, oc, s_k

        def prog(W, opt_state):
            fn = self._cached(
                f"all_mean{int(sync_momentum)}", (W, opt_state),
                lambda: self._shmap(
                    chunk, (self._stacked(W), self._stacked(opt_state)),
                    (self._stacked(W), self._stacked(opt_state), P()),
                    out_shardings=self._pin(
                        lambda: self._param_shardings(W),
                        lambda: self._opt_shardings(opt_state, W), None)))
            return fn(W, opt_state)

        return prog

    def _lower_opt_mean(self, op):
        def chunk(oc):
            return _tm(lambda x: jnp.broadcast_to(
                self._leaf_mean(x), x.shape).astype(x.dtype), oc)

        def prog(opt_state):
            if not _leaves(opt_state):
                return opt_state
            # the pin reuses the parameter rules directly on the optimizer
            # tree — its paths are the param paths under a state-key prefix
            # and the rules are suffix-anchored, so buffers land on the
            # same TP layout put_opt gave them
            fn = self._cached("opt_mean", (opt_state,), lambda: self._shmap(
                chunk, (self._stacked(opt_state),),
                self._stacked(opt_state),
                out_shardings=(self._param_shardings(opt_state)
                               if self.placement == "replica_tp" else None)))
            return fn(opt_state)

        return prog

    def _lower_inner_mean(self, op):
        g = int(op.group)

        def build(W):
            r_local = _leaves(W)[0].shape[0] // self.n_replica_devices
            if r_local and r_local % g == 0:
                # groups fall inside one device's chunk: pure local reshape
                def chunk(Wc):
                    return avg.group_sync(Wc, g)
            elif r_local and g % r_local == 0:
                groups = self._device_groups(g // r_local)
                ax = self.replica_axes[-1]

                def chunk(Wc):
                    def leaf(x):
                        m = jax.lax.pmean(
                            jnp.mean(x.astype(jnp.float32), 0, keepdims=True),
                            ax, axis_index_groups=groups)
                        return jnp.broadcast_to(m, x.shape).astype(x.dtype)
                    return _tm(leaf, Wc)
            else:
                raise NotImplementedError(
                    f"group_size={g} does not align with {r_local} local "
                    f"replicas per device")
            return self._shmap(
                chunk, (self._stacked(W),), self._stacked(W),
                out_shardings=(self._param_shardings(W)
                               if self.placement == "replica_tp" else None))

        def prog(W):
            return self._cached(f"inner{g}", (W,), lambda: build(W))(W)

        return prog

    def _device_groups(self, devices_per_group: int):
        """Contiguous device groups along the innermost replica axis.
        Groups crossing the pod boundary are not supported — the point of
        the hierarchy is that they never should."""
        sizes = dict(self.mesh.shape)
        inner = sizes[self.replica_axes[-1]]
        if devices_per_group > inner or inner % devices_per_group:
            raise NotImplementedError(
                f"replica groups spanning {devices_per_group} devices do "
                f"not tile the '{self.replica_axes[-1]}' axis (size {inner})")
        return [list(range(i, i + devices_per_group))
                for i in range(0, inner, devices_per_group)]

    def _lower_quantized_all_mean(self, op):
        """Byte-true QSGD anchor-delta exchange: each device quantizes its
        replica chunk's deltas to (int8 levels, per-tensor f32 norms) and
        the **levels+norms pair is what crosses the replica axes** — one
        tiled all-gather of ~bits/32 of the f32 volume plus the norm
        side-channel, exactly the payload ``op.wire_bytes`` prices.  Every
        device dequantizes at the receiver and reduces the full stacked
        deltas locally, which makes the mean (and the probe S_k) the same
        reduction the vmap backend runs — the quantized path is
        bit-matched across backends and placements, not merely close.  The
        old path moved *dequantized f32* over the mesh (ROADMAP item).
        Kernel routing is platform-keyed (TPU -> Pallas), matching the
        vmap backend's choice exactly — see the note there."""
        bits = op.wire.bits
        use_kernel = jax.default_backend() == "tpu"

        def chunk(Wc, anchor, key, ridx):
            delta = _tm(lambda w, a: w.astype(jnp.float32) - a[None],
                        Wc, anchor)
            keys = self._local_keys(key, ridx)
            levels, norms = jax.vmap(
                lambda d, k: qsgd_mod.quantize_split_pytree(
                    d, k, bits, use_kernel=use_kernel))(delta, keys)
            # the wire: int8 levels + norms, gathered over the replica axes
            def gather(x):
                return jax.lax.all_gather(x, self.replica_axes, axis=0,
                                          tiled=True)
            levels = _tm(gather, levels)
            norms = _tm(gather, norms)
            dq = qsgd_mod.dequantize_split_pytree(levels, norms, bits)
            mean_d = _tm(lambda d: jnp.mean(d, axis=0), dq)
            s_k = sum(jnp.sum(jnp.square(d - m[None])) / d.shape[0]
                      for d, m in zip(_leaves(dq), _leaves(mean_d)))
            new_anchor = _tm(lambda a, m: a + m, anchor, mean_d)
            Wn = _tm(lambda w, a: jnp.broadcast_to(a[None], w.shape)
                     .astype(w.dtype), Wc, new_anchor)
            return Wn, new_anchor, s_k

        def prog(W, anchor, key):
            # fully-manual region even under replica_tp: the partitioner
            # rejects all_gather inside partial-auto (manual-subgroup)
            # regions, so the model shards re-materialize at region entry
            # over the fast intra-replica ICI — the *cross-replica* wire
            # (the link the paper prices) still carries only int8 levels +
            # norms, and out_shardings pins the TP layout right back
            fn = self._cached("qam", (W, anchor), lambda: self._shmap(
                chunk,
                (self._stacked(W), self._replicated(anchor), P(),
                 P(self._entry)),
                (self._stacked(W), self._replicated(anchor), P()),
                out_shardings=self._pin(
                    lambda: self._param_shardings(W), None, None),
                auto=frozenset()))
            return fn(W, anchor, key, self._replica_index())

        return prog

    def _lower_mean_delta(self, op):
        def chunk(Wc):
            means = _tm(self._leaf_mean, Wc)
            s_k = self._probe(Wc, means)
            delta = _tm(lambda x, m: m - x.astype(jnp.float32), Wc, means)
            return delta, s_k

        def prog(W):
            # the delta is parameter-shaped strategy state held for `delay`
            # steps (DaSGD) — pin it to the TP layout so it never sits
            # model-replicated on the mesh
            fn = self._cached("mean_delta", (W,), lambda: self._shmap(
                chunk, (self._stacked(W),), (self._stacked(W), P()),
                out_shardings=self._pin(lambda: self._param_shardings(W), None)))
            return fn(W)

        return prog

    def collapse(self, W: Pytree) -> Pytree:
        # eager global mean works on sharded arrays; result is unsharded
        return avg.replica_mean(W)
