"""The pluggable execution-backend API.

A ``CommunicationStrategy`` decides *when* and *what* replicas exchange; an
``ExecutionBackend`` decides *where the replicas live* and *how the exchange
is executed*.  The backend owns device placement, the layout of the leading
replica axis, and the collective primitives, so a strategy compiles the same
policy against any topology:

* ``VmapBackend``  — all R replicas on the host's default device, programs
  built with ``vmap`` + ``jnp.mean`` (the PR-1 behavior, bit-exact).
* ``MeshBackend``  — the replica axis sharded over the ``data``/``pod`` axes
  of a real ``jax.sharding.Mesh`` (``launch/mesh.py``), programs built with
  ``shard_map`` and syncs lowered to ``jax.lax.pmean``/``psum`` on the
  replica mesh axes.

Strategies never hand-roll ``vmap`` or ``jnp.mean(axis=0)``; they ask the
backend for pre-built device programs:

* ``replica_step(loss_fn, optimizer)`` — independent local SGD step per
  replica, **zero replica-axis collectives** (Algorithm 1 lines 3-4).
* ``all_mean(sync_momentum=...)``      — the parameter average plus the
  paper's variance probe S_k (Algorithm 2 lines 10-11); the only program
  with a full replica-axis collective.
* ``quantized_all_mean(bits)``         — QSGD-quantized delta-from-anchor
  exchange (qsgd_periodic composition).
* ``inner_mean(group_size)``           — in-group (in-pod) partial average
  for the hierarchical strategy.
* ``mean_delta()`` / ``apply_delta()`` — deferred correction pair for
  DaSGD-style delayed averaging.
* ``full_step`` / ``qsgd_step``        — every-step gradient-averaging
  baselines (FULLSGD, QSGD).

Placement hooks (``put_params`` / ``put_opt`` / ``put_replicated`` /
``init_opt_state``) let the engine and the checkpoint layer stay
backend-agnostic: a checkpoint saved under one backend restores under any
other (``checkpoint/io.py`` saves host arrays; the engine re-``put``s them
through the active backend).

Backends register by name (``@register_backend``); ``--backend=vmap|mesh``
on the train driver selects one.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Type

import jax

from repro.core import averaging as avg
from repro.core.comm_model import ring_allreduce_bytes

Pytree = Any

# Communication shape of every backend program, keyed by program name:
# (is_step, collective, bytes_scale).  ``is_step`` programs charge the
# per-step compute cost on a SimulatedClock; ``collective`` (None = no
# cross-replica exchange) and ``bytes_scale`` (x the full-precision ring
# all-reduce volume) price the exchange -- quantized programs move
# ``bits/32`` of the volume as a gather+broadcast (latency NOT reduced,
# paper §IV), ``inner_mean`` prices a ring *within one group* (the clock
# receives the group size, not the world size).  See runtime/clock.py and
# core/comm_model.COLLECTIVE_HOPS.
PROGRAM_COMM: Dict[str, tuple] = {
    "replica_step": (True, None, 0.0),
    "full_step": (True, "all_reduce", 1.0),
    "qsgd_step": (True, "gather_bcast", None),      # None -> bits/32
    "all_mean": (False, "all_reduce", 1.0),
    "opt_mean": (False, "all_reduce", 1.0),
    "quantized_all_mean": (False, "gather_bcast", None),
    "inner_mean": (False, "inner_mean", 1.0),
    "mean_delta": (False, "all_reduce", 1.0),
    "apply_delta": (False, None, 0.0),              # collective-free add
}


class ExecutionBackend:
    """Base class; concrete backends override placement + program builders.

    ``use_kernel`` selects the fused Pallas mean+sqdev kernel inside
    ``all_mean`` where the backend supports it: ``True``/``False`` force it,
    ``None`` (default) enables it only where profitable — on TPU, where the
    Mosaic kernel fuses the two passes; on CPU interpret-mode it loses badly
    (see ``benchmarks/kernel_bench.py``).
    """

    name = "base"

    def __init__(self, *, use_kernel: Optional[bool] = None):
        if use_kernel is None:
            use_kernel = jax.default_backend() == "tpu"
        self.use_kernel = bool(use_kernel)
        self.n_replicas: Optional[int] = None
        self.clock = None              # telemetry clock (runtime/clock.py)

    # ------------------------------------------------------------- topology
    def bind(self, n_replicas: int) -> None:
        """Fix the replica count this backend will lay out.  Called once by
        the engine before any placement; backends validate divisibility
        against their device topology here."""
        self.n_replicas = int(n_replicas)

    def describe(self) -> Dict[str, Any]:
        """Telemetry: where the replicas live (benchmarks record this)."""
        return {"backend": self.name, "n_replicas": self.n_replicas,
                "n_devices": 1}

    # ------------------------------------------------------------ telemetry
    def set_clock(self, clock) -> None:
        """Bind a ``runtime/clock.py`` Clock.  Every program built by this
        backend is wrapped by ``timed``; the wrapper consults ``self.clock``
        at call time, so binding before or after compilation both work and
        ``None`` (the default) keeps dispatch entirely un-instrumented."""
        self.clock = clock

    def timed(self, name: str, fn: Callable, *, bits: Optional[int] = None,
              group_size: Optional[int] = None) -> Callable:
        """Wrap a compiled program so each invocation reports one
        ``(compute_s, comm_s, bytes)`` record into the bound clock's
        ``Timeline``.  The communication shape comes from ``PROGRAM_COMM``;
        bytes are computed per invocation from the stacked operand (its
        leaf sizes / n_replicas = per-replica parameter count), so one
        wrapper serves every shape the program is dispatched with."""
        is_step, collective, scale = PROGRAM_COMM[name]
        if scale is None:
            scale = (bits or 32) / 32.0

        def wrapped(*args):
            clock = self.clock
            if clock is None:
                return fn(*args)
            nbytes, n = 0.0, self.n_replicas or 1
            if collective is not None:
                if name == "inner_mean" and group_size:
                    n = int(group_size)
                tree = args[0]
                n_params = sum(
                    x.size for x in jax.tree_util.tree_leaves(tree))
                n_params //= max(1, self.n_replicas or 1)
                nbytes = ring_allreduce_bytes(n_params, n) * scale
            return clock.measure(name, fn, args, is_step=is_step,
                                 comm_bytes=nbytes, collective=collective,
                                 n_nodes=n)

        return wrapped

    # ------------------------------------------------------------ placement
    def put_params(self, W: Pytree) -> Pytree:
        """Place a replica-stacked parameter pytree on this backend's
        devices (identity for the host backend)."""
        return W

    def put_opt(self, opt_state: Pytree, W: Pytree) -> Pytree:
        """Place a replica-stacked optimizer state (mirrors ``W``'s
        layout; scalar counters replicate)."""
        return opt_state

    def put_replicated(self, tree: Pytree) -> Pytree:
        """Place an *unstacked* pytree replicated on every device (e.g. the
        qsgd_periodic full-precision anchor)."""
        return tree

    def get(self, tree: Pytree) -> Pytree:
        """Fetch to host numpy (checkpoint save path)."""
        return jax.device_get(tree)

    def init_opt_state(self, optimizer, W: Pytree) -> Pytree:
        return self.put_opt(jax.vmap(optimizer.init)(W), W)

    def collapse(self, W: Pytree) -> Pytree:
        """Replica mean without the probe — a host-side convenience (anchor
        seeding, export checkpoints)."""
        return avg.replica_mean(W)

    def default_group_size(self) -> Optional[int]:
        """Topology-derived hierarchical group size (replicas per pod on a
        multi-pod mesh), or None when the backend has no natural group
        boundary — the hierarchical strategy then falls back to its
        config/heuristic choice."""
        return None

    # ------------------------------------------------- program builders
    # Every builder returns a compiled callable; signatures mirror the
    # core/averaging.py programs so VmapBackend is a thin wrapper.

    def replica_step(self, loss_fn, optimizer) -> Callable:
        """(W, opt_state, batch, lr) -> (W, opt_state, metrics); no
        replica-axis collectives."""
        raise NotImplementedError

    def full_step(self, loss_fn, optimizer) -> Callable:
        """(W, opt_state, batch, lr) -> (W, opt_state, metrics); gradients
        all-reduced every call (FULLSGD)."""
        raise NotImplementedError

    def qsgd_step(self, loss_fn, optimizer, bits: int) -> Callable:
        """(W, opt_state, batch, lr, key) -> (W, opt_state, metrics);
        quantized gradient exchange every call (QSGD)."""
        raise NotImplementedError

    def all_mean(self, *, sync_momentum: bool = False) -> Callable:
        """(W, opt_state) -> (W, opt_state, s_k): the replica average and
        the paper's variance probe."""
        raise NotImplementedError

    def inner_mean(self, group_size: int) -> Callable:
        """(W) -> W averaged within contiguous replica groups of
        ``group_size`` (hierarchical in-pod sync)."""
        raise NotImplementedError

    def quantized_all_mean(self, bits: int) -> Callable:
        """(W, anchor, key) -> (W, new_anchor, s_k): QSGD-quantized deltas
        from the full-precision anchor, averaged and re-applied."""
        raise NotImplementedError

    def opt_mean(self) -> Callable:
        """(opt_state) -> opt_state averaged across replicas."""
        raise NotImplementedError

    def mean_delta(self) -> Callable:
        """(W) -> (delta, s_k) with ``delta_i = mean(W) - W_i`` (stacked):
        the correction DaSGD applies ``delay`` steps later."""
        raise NotImplementedError

    def apply_delta(self) -> Callable:
        """(W, delta) -> W + delta, elementwise (no collectives — the
        collective already happened in ``mean_delta``)."""
        if not hasattr(self, "_apply_delta_fn"):
            import jax.numpy as jnp

            def apply(W, delta):
                return jax.tree_util.tree_map(
                    lambda w, d: (w.astype(jnp.float32) + d).astype(w.dtype),
                    W, delta)
            self._apply_delta_fn = jax.jit(apply)
        return self.timed("apply_delta", self._apply_delta_fn)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_BACKENDS: Dict[str, Type[ExecutionBackend]] = {}


def register_backend(cls: Type[ExecutionBackend]):
    """Class decorator: register under ``cls.name``."""
    if not cls.name or cls.name == "base":
        raise ValueError(f"{cls.__name__} needs a unique .name")
    _BACKENDS[cls.name] = cls
    return cls


def get_backend_cls(name: str) -> Type[ExecutionBackend]:
    if name not in _BACKENDS:
        raise KeyError(
            f"unknown backend '{name}'; available: {available_backends()}")
    return _BACKENDS[name]


def make_backend(name: str, **kw) -> ExecutionBackend:
    return get_backend_cls(name)(**kw)


def available_backends() -> List[str]:
    return sorted(_BACKENDS)


def resolve_backend(backend) -> ExecutionBackend:
    """None -> default VmapBackend; str -> registry; instance -> itself."""
    if backend is None:
        backend = "vmap"
    if isinstance(backend, str):
        return make_backend(backend)
    if not isinstance(backend, ExecutionBackend):
        raise TypeError(f"expected backend name or ExecutionBackend, "
                        f"got {type(backend).__name__}")
    return backend
