"""The pluggable execution-backend API.

A ``CommunicationStrategy`` decides *when* and *what* replicas exchange; an
``ExecutionBackend`` decides *where the replicas live* and *how the exchange
is executed*.  The backend owns device placement, the layout of the leading
replica axis, and the collective primitives, so a strategy compiles the same
policy against any topology:

* ``VmapBackend``  — all R replicas on the host's default device, programs
  built with ``vmap`` + ``jnp.mean`` (the PR-1 behavior, bit-exact).
* ``MeshBackend``  — the replica axis sharded over the ``data``/``pod`` axes
  of a real ``jax.sharding.Mesh`` (``launch/mesh.py``), programs built with
  ``shard_map`` and syncs lowered to real collectives.

Strategies never hand-roll ``vmap`` or ``jnp.mean(axis=0)``; they emit
**``CollectiveOp`` descriptors** (``backends/ops.py``) and ask the backend
to lower them to compiled device programs:

    program = backend.lower(op, loss_fn=..., optimizer=...)

The descriptor carries the collective kind, wire format, group, and overlap
hint; lowering resolves ``op.name`` to the backend's ``_lower_<name>``
builder and wraps the compiled program so every invocation is priced *from
the descriptor itself* (``op.wire_bytes``) into the bound telemetry clock —
the old hand-synchronized ``PROGRAM_COMM`` table is gone.  Ops with
``overlap=True`` dispatch asynchronously and return an ``InFlightOp``
handle fetched later (DaSGD's delayed correction).

The named convenience builders (``replica_step`` / ``all_mean`` /
``inner_mean`` / ``quantized_all_mean`` / ``mean_delta`` / ``apply_delta``
/ ``full_step`` / ``qsgd_step`` / ``opt_mean``) remain as thin sugar over
``lower(<canonical op>)`` for tests and benchmarks.

Placement hooks (``put_params`` / ``put_opt`` / ``put_replicated`` /
``init_opt_state``) let the engine and the checkpoint layer stay
backend-agnostic: a checkpoint saved under one backend restores under any
other (``checkpoint/io.py`` saves host arrays; the engine re-``put``s them
through the active backend).

Backends register by name (``@register_backend``); ``--backend=vmap|mesh``
on the train driver selects one.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Type

import jax

from repro.backends import ops as collective_ops
from repro.backends.ops import CollectiveOp, InFlightOp
from repro.core import averaging as avg

Pytree = Any


class ExecutionBackend:
    """Base class; concrete backends override placement + ``_lower_*``
    program builders.

    ``use_kernel`` selects the fused Pallas mean+sqdev kernel inside
    ``all_mean`` where the backend supports it: ``True``/``False`` force
    it, ``None`` (default) enables it only where profitable — on TPU; on
    CPU interpret-mode it loses badly (see ``benchmarks/kernel_bench.py``).
    The QSGD *quantization* kernels are deliberately NOT governed by this
    flag: their routing is platform-keyed (TPU -> Pallas, else reference
    math) identically on every backend, because the byte-true exchange's
    cross-backend bit-match requires all backends to round the same way
    (see ``_lower_quantized_all_mean`` on vmap/mesh).
    """

    name = "base"

    def __init__(self, *, use_kernel: Optional[bool] = None):
        if use_kernel is None:
            use_kernel = jax.default_backend() == "tpu"
        self.use_kernel = bool(use_kernel)
        self.n_replicas: Optional[int] = None
        self.clock = None              # telemetry clock (runtime/clock.py)

    # ------------------------------------------------------------- topology
    def bind(self, n_replicas: int) -> None:
        """Fix the replica count this backend will lay out.  Called once by
        the engine before any placement; backends validate divisibility
        against their device topology here."""
        self.n_replicas = int(n_replicas)

    def describe(self) -> Dict[str, Any]:
        """Telemetry: where the replicas live (benchmarks record this)."""
        return {"backend": self.name, "n_replicas": self.n_replicas,
                "n_devices": 1}

    # ------------------------------------------------------------ telemetry
    def set_clock(self, clock) -> None:
        """Bind a ``runtime/clock.py`` Clock.  Every program lowered by this
        backend is wrapped by ``timed``; the wrapper consults ``self.clock``
        at call time, so binding before or after compilation both work and
        ``None`` (the default) keeps dispatch entirely un-instrumented."""
        self.clock = clock

    def timed(self, op: CollectiveOp, fn: Callable) -> Callable:
        """Wrap a compiled program so each invocation reports one
        ``(compute_s, comm_s, bytes)`` record into the bound clock's
        ``Timeline``.  The communication shape comes solely from the op
        descriptor: bytes are ``op.wire_bytes`` of the per-replica
        parameter count (read off the stacked operand per invocation, so
        one wrapper serves every shape), the collective kind and group
        ride the op, and ``overlap=True`` ops dispatch asynchronously —
        the wrapper returns an ``InFlightOp`` whose ``fetch()`` settles
        the exchange with the clock later."""

        def wrapped(*args):
            clock = self.clock
            if clock is None:
                out = fn(*args)
                return InFlightOp(op, out) if op.overlap else out
            n = self.n_replicas or 1
            nbytes = 0.0
            if op.collective is not None:
                if op.group:
                    n = int(op.group)
                leaves = jax.tree_util.tree_leaves(args[0])
                n_params = (sum(x.size for x in leaves)
                            // max(1, self.n_replicas or 1))
                nbytes = op.wire_bytes(n_params, n, n_tensors=len(leaves))
            if op.overlap:
                out, rec = clock.dispatch_async(
                    op.name, fn, args, comm_bytes=nbytes,
                    collective=op.collective, n_nodes=n)
                return InFlightOp(op, out, clock, rec)
            return clock.measure(op.name, fn, args, is_step=op.is_step,
                                 comm_bytes=nbytes, collective=op.collective,
                                 n_nodes=n)

        return wrapped

    # ------------------------------------------------------------- lowering
    def lower(self, op: CollectiveOp, **builder_kw) -> Callable:
        """Lower one ``CollectiveOp`` descriptor to a compiled, timed
        program.  ``op.name`` resolves to this backend's ``_lower_<name>``
        builder; parameters the op itself carries (wire bits, group size,
        overlap) are read off the descriptor, anything host-side (loss_fn,
        optimizer, sync_momentum) arrives as builder kwargs."""
        build = getattr(self, f"_lower_{op.name}", None)
        if build is None:
            raise KeyError(
                f"backend '{self.name}' cannot lower op '{op.name}'")
        return self.timed(op, build(op, **builder_kw))

    # ---------------------------------------------- named-op sugar
    # Thin wrappers over lower(<canonical op>) — tests and benchmarks call
    # these; strategies emit the descriptors directly.

    def replica_step(self, loss_fn, optimizer) -> Callable:
        """(W, opt_state, batch, lr) -> (W, opt_state, metrics); no
        replica-axis collectives."""
        return self.lower(collective_ops.replica_step_op(),
                          loss_fn=loss_fn, optimizer=optimizer)

    def full_step(self, loss_fn, optimizer) -> Callable:
        """(W, opt_state, batch, lr) -> (W, opt_state, metrics); gradients
        all-reduced every call (FULLSGD)."""
        return self.lower(collective_ops.full_step_op(),
                          loss_fn=loss_fn, optimizer=optimizer)

    def qsgd_step(self, loss_fn, optimizer, bits: int) -> Callable:
        """(W, opt_state, batch, lr, key) -> (W, opt_state, metrics);
        quantized gradient exchange every call (QSGD)."""
        return self.lower(collective_ops.qsgd_step_op(bits),
                          loss_fn=loss_fn, optimizer=optimizer)

    def all_mean(self, *, sync_momentum: bool = False) -> Callable:
        """(W, opt_state) -> (W, opt_state, s_k): the replica average and
        the paper's variance probe."""
        return self.lower(collective_ops.all_mean_op(),
                          sync_momentum=sync_momentum)

    def inner_mean(self, group_size: int) -> Callable:
        """(W) -> W averaged within contiguous replica groups of
        ``group_size`` (hierarchical in-pod sync)."""
        return self.lower(collective_ops.inner_mean_op(group_size))

    def quantized_all_mean(self, bits: int) -> Callable:
        """(W, anchor, key) -> (W, new_anchor, s_k): byte-true QSGD deltas
        from the full-precision anchor — int8 levels + norms on the wire,
        dequantized at the receiver, averaged and re-applied."""
        return self.lower(collective_ops.quantized_all_mean_op(bits))

    def opt_mean(self) -> Callable:
        """(opt_state) -> opt_state averaged across replicas."""
        return self.lower(collective_ops.opt_mean_op())

    def mean_delta(self, *, overlap: bool = False) -> Callable:
        """(W) -> (delta, s_k) with ``delta_i = mean(W) - W_i`` (stacked):
        the correction DaSGD applies ``delay`` steps later.  With
        ``overlap=True`` the call returns an ``InFlightOp`` immediately."""
        return self.lower(collective_ops.mean_delta_op(overlap=overlap))

    def apply_delta(self) -> Callable:
        """(W, delta) -> W + delta, elementwise (no collectives — the
        collective already happened in ``mean_delta``)."""
        return self.lower(collective_ops.apply_delta_op())

    # ------------------------------------------------------------ placement
    def put_params(self, W: Pytree) -> Pytree:
        """Place a replica-stacked parameter pytree on this backend's
        devices (identity for the host backend)."""
        return W

    def put_opt(self, opt_state: Pytree, W: Pytree) -> Pytree:
        """Place a replica-stacked optimizer state (mirrors ``W``'s
        layout; scalar counters replicate)."""
        return opt_state

    def put_replicated(self, tree: Pytree) -> Pytree:
        """Place an *unstacked* pytree replicated on every device (e.g. the
        qsgd_periodic full-precision anchor)."""
        return tree

    def get(self, tree: Pytree) -> Pytree:
        """Fetch to host numpy (checkpoint save path)."""
        return jax.device_get(tree)

    def init_opt_state(self, optimizer, W: Pytree) -> Pytree:
        return self.put_opt(jax.vmap(optimizer.init)(W), W)

    def collapse(self, W: Pytree) -> Pytree:
        """Replica mean without the probe — a host-side convenience (anchor
        seeding, export checkpoints)."""
        return avg.replica_mean(W)

    def default_group_size(self) -> Optional[int]:
        """Topology-derived hierarchical group size (replicas per pod on a
        multi-pod mesh), or None when the backend has no natural group
        boundary — the hierarchical strategy then falls back to its
        config/heuristic choice."""
        return None

    # ------------------------------------------------- shared lowerings
    def _lower_apply_delta(self, op: CollectiveOp):
        """Elementwise add, shared by every backend.  Buffers are donated
        where donation is real (TPU/GPU): the pre-correction W and the
        fetched delta are both dead after the add, so the overlap window
        never holds a third parameter-sized buffer."""
        import jax.numpy as jnp

        def apply(W, delta):
            return jax.tree_util.tree_map(
                lambda w, d: (w.astype(jnp.float32) + d).astype(w.dtype),
                W, delta)

        donate = (0, 1) if jax.default_backend() in ("tpu", "gpu") else ()
        return jax.jit(apply, donate_argnums=donate)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_BACKENDS: Dict[str, Type[ExecutionBackend]] = {}


def register_backend(cls: Type[ExecutionBackend]):
    """Class decorator: register under ``cls.name``."""
    if not cls.name or cls.name == "base":
        raise ValueError(f"{cls.__name__} needs a unique .name")
    _BACKENDS[cls.name] = cls
    return cls


def get_backend_cls(name: str) -> Type[ExecutionBackend]:
    if name not in _BACKENDS:
        raise KeyError(
            f"unknown backend '{name}'; available: {available_backends()}")
    return _BACKENDS[name]


def make_backend(name: str, **kw) -> ExecutionBackend:
    return get_backend_cls(name)(**kw)


def available_backends() -> List[str]:
    return sorted(_BACKENDS)


def resolve_backend(backend) -> ExecutionBackend:
    """None -> default VmapBackend; str -> registry; instance -> itself."""
    if backend is None:
        backend = "vmap"
    if isinstance(backend, str):
        return make_backend(backend)
    if not isinstance(backend, ExecutionBackend):
        raise TypeError(f"expected backend name or ExecutionBackend, "
                        f"got {type(backend).__name__}")
    return backend
