"""Pluggable execution backends (see base.py for the API).

Importing this package registers the built-in backends: ``vmap`` (host
device, PR-1 behavior, bit-exact) and ``mesh`` (``shard_map`` over a real
device mesh, replica axis sharded over ``data``/``pod``).
"""
from repro.backends.base import (  # noqa: F401
    ExecutionBackend, available_backends, get_backend_cls, make_backend,
    register_backend, resolve_backend,
)
from repro.backends.vmap import VmapBackend  # noqa: F401
from repro.backends.mesh import MeshBackend  # noqa: F401
