"""Pluggable execution backends (see base.py for the API).

Importing this package registers the built-in backends: ``vmap`` (host
device, PR-1 behavior, bit-exact) and ``mesh`` (``shard_map`` over a real
device mesh, replica axis sharded over ``data``/``pod``).  The
communication layer's vocabulary — the ``CollectiveOp`` descriptors
strategies emit and backends lower — lives in ``backends/ops.py``
(DESIGN.md §8).
"""
from repro.backends.base import (  # noqa: F401
    ExecutionBackend, available_backends, get_backend_cls, make_backend,
    register_backend, resolve_backend,
)
from repro.backends.ops import CollectiveOp, InFlightOp, WireFormat  # noqa: F401
from repro.backends.vmap import VmapBackend  # noqa: F401
from repro.backends.mesh import MeshBackend  # noqa: F401
