"""CollectiveOp IR — the declarative communication layer (DESIGN.md §8).

What a sync actually *sends* used to live in three hand-synchronized
places: the backend's named program builders, the ``PROGRAM_COMM`` table in
``backends/base.py``, and the per-collective latency hops in
``core/comm_model.py``.  This module replaces the first two with one typed
descriptor: a ``CollectiveOp`` names the collective kind, the wire format
of the payload, the participating group, and whether the exchange may
*overlap* compute.  Everything downstream derives from the descriptor:

* **lowering** — ``ExecutionBackend.lower(op, ...)`` turns a descriptor
  into a compiled device program (``_lower_<name>`` builders on each
  backend), wrapped so every invocation is priced;
* **pricing**  — ``op.wire_bytes(n_params, n_nodes, n_tensors)`` is the
  single source of bytes for ``SimulatedClock`` / ``comm_model``: a ring
  exchange of the wire-format payload, ``2(n−1)/n × payload`` per node
  (``f32``: 4 bytes/component; ``qsgd_int8{bits}``: ``bits/8`` per
  component plus the per-tensor norm side-channel);
* **latency**  — ``op.collective`` keys ``comm_model.COLLECTIVE_HOPS``
  (all_reduce = 2(n−1) hops, gather_bcast unreduced, inner_mean prices
  the group);
* **overlap**  — ``overlap=True`` ops dispatch asynchronously and return
  an ``InFlightOp`` handle; the caller fetches the results later (DaSGD's
  delayed correction), and the clock records the exchange off the step
  path.

Strategies emit these descriptors (``CommunicationStrategy.sync_op`` /
``step_op``) and hand them to the backend; accounting hooks price the same
descriptors, so the bytes a benchmark reports are the bytes the lowered
program models — one truth, not three tables.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

# ---------------------------------------------------------------------------
# Wire formats
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class WireFormat:
    """How one parameter component travels: ``f32`` (4 bytes) or
    ``qsgd_int8`` (``bits/8`` bytes of stochastic-quantization levels plus a
    per-tensor f32 norm side-channel — ``norm_bytes_per_tensor = 0`` keeps
    the paper's §IV accounting, which treats the norms as negligible)."""

    kind: str = "f32"               # "f32" | "qsgd_int8"
    bits: int = 32                  # bits per component on the wire
    norm_bytes_per_tensor: int = 0  # side-channel bytes (qsgd norms)


F32 = WireFormat()


def qsgd_wire(bits: int, *, norms: bool = True) -> WireFormat:
    """QSGD levels: ``bits``-bit components (+ 4-byte per-tensor norms when
    ``norms`` — the byte-true anchor-delta exchange counts them; the
    every-step gradient baseline keeps the paper's levels-only charge)."""
    return WireFormat("qsgd_int8", int(bits), 4 if norms else 0)


# ---------------------------------------------------------------------------
# The op descriptor
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CollectiveOp:
    """One backend program, declaratively.

    ``name`` doubles as the Timeline/program key; ``collective`` is a
    ``comm_model.COLLECTIVE_HOPS`` kind (None = no cross-replica exchange);
    ``is_step`` programs charge per-step compute on a ``SimulatedClock``;
    ``group`` restricts the exchange to that many replicas (hierarchical
    inner mean — pricing then sees the group, never the world); ``overlap``
    ops dispatch without blocking the step path and return an
    ``InFlightOp``."""

    name: str
    collective: Optional[str] = None
    is_step: bool = False
    wire: WireFormat = field(default_factory=WireFormat)
    group: Optional[int] = None
    overlap: bool = False

    # ------------------------------------------------------------- pricing
    def payload_bytes(self, n_params: int, n_tensors: int = 0) -> float:
        """Bytes one node puts on the wire per event."""
        return (n_params * self.wire.bits / 8.0
                + n_tensors * self.wire.norm_bytes_per_tensor)

    def wire_bytes(self, n_params: int, n_nodes: int,
                   n_tensors: int = 0) -> float:
        """Per-node bytes moved by one invocation over ``n_nodes`` — a
        bandwidth-optimal ring moves ``2(n−1)/n`` of the payload per node
        (Patarasuk-Yuan; ``comm_model.ring_allreduce_bytes`` is the f32
        special case).  0 for collective-free ops."""
        if self.collective is None or n_nodes <= 1:
            return 0.0
        return (2.0 * (n_nodes - 1) / n_nodes
                * self.payload_bytes(n_params, n_tensors))


# ---------------------------------------------------------------------------
# Canonical ops — the vocabulary strategies emit
# ---------------------------------------------------------------------------


def replica_step_op() -> CollectiveOp:
    """Independent local SGD step per replica; zero replica-axis
    collectives (Algorithm 1 lines 3-4)."""
    return CollectiveOp("replica_step", None, is_step=True)


def full_step_op() -> CollectiveOp:
    """FULLSGD: gradients ring-all-reduced every step."""
    return CollectiveOp("full_step", "all_reduce", is_step=True)


def qsgd_step_op(bits: int) -> CollectiveOp:
    """QSGD baseline: quantized gradients every step.  Levels are not
    ring-reducible -> gather+broadcast, latency NOT reduced (paper §IV);
    the paper's accounting charges bits/32 of the volume, norms excluded."""
    return CollectiveOp("qsgd_step", "gather_bcast", is_step=True,
                        wire=qsgd_wire(bits, norms=False))


def all_mean_op() -> CollectiveOp:
    """The replica parameter mean + variance probe S_k (Algorithm 2
    lines 10-11) — one full-precision ring all-reduce."""
    return CollectiveOp("all_mean", "all_reduce")


def opt_mean_op() -> CollectiveOp:
    """Optimizer-state mean across replicas (sync_momentum knob)."""
    return CollectiveOp("opt_mean", "all_reduce")


def quantized_all_mean_op(bits: int) -> CollectiveOp:
    """Byte-true QSGD anchor-delta exchange: int8 levels + per-tensor
    norms are all-gathered and dequantized at the receiver, so the wire
    carries ~bits/32 of the f32 volume plus the norm side-channel."""
    return CollectiveOp("quantized_all_mean", "gather_bcast",
                        wire=qsgd_wire(bits))


def inner_mean_op(group_size: int) -> CollectiveOp:
    """Hierarchical in-group (in-pod) partial average: a ring within one
    group of ``group_size`` replicas — priced on the group, never the
    world, and on the fast intra-pod link."""
    return CollectiveOp("inner_mean", "inner_mean", group=int(group_size))


def mean_delta_op(*, overlap: bool = False) -> CollectiveOp:
    """DaSGD correction snapshot ``w̄ − w_i`` (the only collective of the
    pair).  ``overlap=True`` dispatches it asynchronously: the caller gets
    an ``InFlightOp`` and fetches ``delay`` steps later."""
    return CollectiveOp("mean_delta", "all_reduce", overlap=overlap)


def apply_delta_op() -> CollectiveOp:
    """Collective-free elementwise add of a previously fetched delta."""
    return CollectiveOp("apply_delta", None)


# ---------------------------------------------------------------------------
# In-flight handle for overlap ops
# ---------------------------------------------------------------------------


class InFlightOp:
    """A dispatched ``overlap=True`` collective whose results have not been
    fetched.  ``fetch()`` returns the program outputs, charging any
    remaining (un-overlapped) communication to the bound clock exactly
    once; jax's async dispatch keeps the device busy in between, so the
    step path never blocked on the exchange."""

    def __init__(self, op: CollectiveOp, outputs, clock=None, record=None):
        self.op = op
        self._outputs = outputs
        self._clock = clock
        self._record = record
        self.fetched = False

    def fetch(self):
        if not self.fetched:
            self.fetched = True
            if self._clock is not None:
                self._clock.complete_async(self.op.name, self._record,
                                           self._outputs)
        return self._outputs
