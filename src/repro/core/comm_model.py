"""Analytic communication model — reproduces the paper's execution-time
figures (Fig 4c/5c/6, §IV) on hardware we don't have, and the TPU roofline
collective term.

The paper's setup: 16 nodes, ring all-reduce (NCCL / bandwidth-optimal
Patarasuk-Yuan), 100 Gbps InfiniBand vs. throttled 10 Gbps.  A ring
all-reduce of D bytes moves 2·(n−1)/n·D per node.
"""
from __future__ import annotations

from dataclasses import dataclass


GBPS_100 = 100e9 / 8     # bytes/s
GBPS_10 = 10e9 / 8
LATENCY_S = 5e-6         # per collective, per hop


@dataclass(frozen=True)
class CommStats:
    bytes_per_node: float
    n_events: int
    time_s: float


def ring_allreduce_bytes(n_params: int, n_nodes: int, bytes_per_el: int = 4) -> float:
    return 2.0 * (n_nodes - 1) / n_nodes * n_params * bytes_per_el


# Latency hops per collective type, keyed by ``CollectiveOp.collective``
# (backends/ops.py) -- the op descriptor names the kind, this table is the
# physics; bytes come from ``op.wire_bytes`` (``ring_allreduce_bytes``
# below is its f32 special case, kept for analytic callers).  A ring
# all-reduce is reduce-scatter + all-gather: 2(n-1) sequential hops.  A
# plain ring all-gather is (n-1).  QSGD's quantized levels are not
# ring-reducible, so the exchange is a gather + broadcast -- 2(n-1) hops,
# i.e. the latency is NOT reduced even though the volume is (paper §IV).
# A hierarchical inner mean is a ring all-reduce *within one group*: the
# group size rides the op (``op.group``) and the hops count that group
# only -- never the full ring (the old unconditional 2(n-1) overcharged
# hierarchical strategies).
COLLECTIVE_HOPS = {
    "all_reduce": lambda n: 2 * (n - 1),
    "all_gather": lambda n: n - 1,
    "gather_bcast": lambda n: 2 * (n - 1),
    "inner_mean": lambda n: 2 * (n - 1),
}


def comm_time(bytes_per_event: float, n_events: int, n_nodes: int,
              bandwidth: float, *, collective: str = "all_reduce",
              latency_s: float = LATENCY_S) -> float:
    """Wall-clock of ``n_events`` collectives of ``bytes_per_event`` each —
    the generic accounting hook the strategy API builds its ``comm_stats``
    on (``CommunicationStrategy.comm_bytes_per_sync`` supplies the bytes).
    ``collective`` picks the latency-hop structure (``COLLECTIVE_HOPS``);
    for ``inner_mean`` pass the *group* size as ``n_nodes``."""
    if collective not in COLLECTIVE_HOPS:
        raise ValueError(f"unknown collective '{collective}'; "
                         f"available: {sorted(COLLECTIVE_HOPS)}")
    lat = latency_s * COLLECTIVE_HOPS[collective](n_nodes)
    return n_events * (bytes_per_event / bandwidth + lat)


def method_comm(method: str, n_params: int, n_nodes: int, total_steps: int,
                n_syncs: int, bandwidth: float, qsgd_bits: int = 8) -> CommStats:
    """Total communication for a training run, per node."""
    coll = "all_reduce"
    if method in ("fullsgd",):
        per = ring_allreduce_bytes(n_params, n_nodes)
        ev = total_steps
    elif method in ("cpsgd", "adpsgd", "decreasing"):
        per = ring_allreduce_bytes(n_params, n_nodes)
        ev = n_syncs
    elif method == "qsgd":
        # 1 byte per component (8-bit levels) + per-tensor norms (negligible);
        # quantized values are not ring-reducible -> gather+broadcast; the
        # paper charges 1/4 of FULLSGD bytes, latency NOT reduced.
        per = ring_allreduce_bytes(n_params, n_nodes) * qsgd_bits / 32.0
        ev = total_steps
        coll = "gather_bcast"
    else:
        raise ValueError(method)
    # prefer strategies.comm_stats_for for new code
    return CommStats(per, ev, comm_time(per, ev, n_nodes, bandwidth,
                                        collective=coll))


def speedup_vs_fullsgd(method: str, n_params: int, n_nodes: int,
                       total_steps: int, n_syncs: int, step_compute_s: float,
                       bandwidth: float) -> float:
    """Modeled wall-clock speedup of `method` over FULLSGD (paper Fig 4c)."""
    full = method_comm("fullsgd", n_params, n_nodes, total_steps,
                       total_steps, bandwidth)
    this = method_comm(method, n_params, n_nodes, total_steps, n_syncs,
                       bandwidth)
    t_full = total_steps * step_compute_s + full.time_s
    t_this = total_steps * step_compute_s + this.time_s
    return t_full / t_this


# --- TPU roofline constants (v5e-class targets; system prompt) -------------
PEAK_FLOPS_BF16 = 197e12         # per chip
HBM_BW = 819e9                   # bytes/s per chip
ICI_BW = 50e9                    # bytes/s per link (~per-axis usable)


def roofline_terms(hlo_flops: float, hlo_bytes: float, collective_bytes: float,
                   n_chips: int, ici_links: int = 1) -> dict:
    c = hlo_flops / (n_chips * PEAK_FLOPS_BF16)
    m = hlo_bytes / (n_chips * HBM_BW)
    x = collective_bytes / (n_chips * ICI_BW * ici_links)
    dom = max((c, "compute"), (m, "memory"), (x, "collective"))
    return {"compute_s": c, "memory_s": m, "collective_s": x,
            "dominant": dom[1], "bound_s": dom[0]}
