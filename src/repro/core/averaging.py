"""The paper's core: periodic parameter averaging over a stacked replica axis.

Representation
--------------
``W`` is a parameter pytree whose every leaf carries a leading **replica
axis** of size R — one local-SGD trajectory per replica (paper: one per
node).  On the production mesh this axis is sharded over the ``data`` (or
``pod``) mesh axis, so:

* ``local_step``  compiles with **zero collectives** on the replica axis —
  each replica advances independently on its own batch shard (Algorithm 1
  lines 3–4 / Algorithm 2 lines 5–7);
* ``sync_replicas`` is the only program with a replica-axis collective: the
  parameter mean (one all-reduce) plus the paper's variance probe
  ``S_k = (1/n) Σ_i ||w̄ − w_i||²`` (Algorithm 2 lines 10–11), which reuses
  the already-materialized deviations — a scalar psum beyond the mean.

This is the TPU-native adaptation of the paper's NCCL ring all-reduce
formulation (see DESIGN.md §2).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim.optimizers import Optimizer

Pytree = Any
LossFn = Callable[[Pytree, Dict[str, jnp.ndarray]], Tuple[jnp.ndarray, Dict]]


def stack_replicas(tree: Pytree, n: int) -> Pytree:
    """Replicate a single-model pytree into n identical local trajectories."""
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (n,) + x.shape) + 0, tree)


def replica_mean(W: Pytree) -> Pytree:
    return jax.tree_util.tree_map(lambda x: jnp.mean(x, axis=0), W)


def n_replicas(W: Pytree) -> int:
    return jax.tree_util.tree_leaves(W)[0].shape[0]


def parameter_variance(W: Pytree) -> jnp.ndarray:
    """Var[W_k] = (1/n) Σ_i ||W̄ − w_i||²  (paper Eq. 7), summed over the
    entire parameter vector, in float32."""
    def leaf_var(x):
        xf = x.astype(jnp.float32)
        mean = jnp.mean(xf, axis=0, keepdims=True)
        return jnp.sum(jnp.square(xf - mean)) / x.shape[0]
    return sum(jax.tree_util.tree_leaves(
        jax.tree_util.tree_map(leaf_var, W)))


def make_replica_step(loss_fn: LossFn, optimizer: Optimizer):
    """Returns the *single-replica* program one_replica(params, opt_state,
    batch, lr) -> (params, opt_state, metrics) — the unit every execution
    backend maps over its replica layout (``vmap`` on one device,
    ``shard_map``+``vmap`` chunks on a mesh)."""
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def one_replica(params, opt_state, batch, lr):
        (loss, aux), grads = grad_fn(params, batch)
        new_params, new_state = optimizer.update(grads, opt_state, params, lr)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree_util.tree_leaves(grads)))
        return new_params, new_state, {"loss": loss, "grad_norm": gnorm, **aux}

    return one_replica


def make_local_step(loss_fn: LossFn, optimizer: Optimizer):
    """Returns step(W, opt_state, batch, lr) -> (W, opt_state, metrics).

    ``batch`` leaves carry the replica axis (R, per_replica_batch, ...).
    vmap over the replica axis keeps trajectories independent; on the mesh
    this axis is sharded so vmap lanes live on distinct replica groups.
    """
    one_replica = make_replica_step(loss_fn, optimizer)

    def step(W, opt_state, batch, lr):
        new_W, new_state, metrics = jax.vmap(
            one_replica, in_axes=(0, 0, 0, None))(W, opt_state, batch, lr)
        metrics = jax.tree_util.tree_map(lambda x: jnp.mean(x, axis=0), metrics)
        return new_W, new_state, metrics

    return step


def sync_replicas(W: Pytree, opt_state: Optional[Pytree] = None, *,
                  sync_momentum: bool = False,
                  use_kernel: bool = False,
                  ) -> Tuple[Pytree, Optional[Pytree], jnp.ndarray]:
    """Average the replicas (Algorithm 2 line 10) and compute the variance
    probe S_k (line 11).  Returns (W_synced, opt_state, S_k)."""
    if use_kernel:
        from repro.kernels import ops as kops
        leaves, treedef = jax.tree_util.tree_flatten(W)
        outs, sks = [], []
        for x in leaves:
            mean, sk = kops.param_mean_and_sqdev(x)
            outs.append(jnp.broadcast_to(mean[None], x.shape).astype(x.dtype))
            sks.append(sk)
        W_new = jax.tree_util.tree_unflatten(treedef, outs)
        S_k = sum(sks) / jax.tree_util.tree_leaves(W)[0].shape[0]
    else:
        def mean_leaf(x):
            return jnp.mean(x.astype(jnp.float32), axis=0, keepdims=True)
        means = jax.tree_util.tree_map(mean_leaf, W)
        S_k = sum(
            jnp.sum(jnp.square(x.astype(jnp.float32) - m)) / x.shape[0]
            for x, m in zip(jax.tree_util.tree_leaves(W),
                            jax.tree_util.tree_leaves(means)))
        W_new = jax.tree_util.tree_map(
            lambda x, m: jnp.broadcast_to(m, x.shape).astype(x.dtype), W, means)
    if opt_state is not None and sync_momentum:
        opt_state = sync_opt_state(opt_state)
    return W_new, opt_state, S_k


def sync_opt_state(opt_state: Pytree) -> Pytree:
    """Average the optimizer state across replicas (beyond-paper knob)."""
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(
            jnp.mean(x.astype(jnp.float32), 0, keepdims=True),
            x.shape).astype(x.dtype), opt_state)


def make_full_step(loss_fn: LossFn, optimizer: Optimizer):
    """FULLSGD baseline: gradients are averaged across replicas every step
    (equivalent to CPSGD with p=1 applied to gradients before the update,
    i.e. vanilla synchronous data-parallel SGD)."""
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(W, opt_state, batch, lr):
        def one(params, batch):
            return grad_fn(params, batch)
        (loss, aux), grads = jax.vmap(one)(W, batch)
        g_mean = jax.tree_util.tree_map(
            lambda g: jnp.mean(g.astype(jnp.float32), axis=0,
                               keepdims=True).astype(g.dtype), grads)
        g_bcast = jax.tree_util.tree_map(
            lambda g, w: jnp.broadcast_to(g, w.shape), g_mean, W)
        new_W, new_state = jax.vmap(
            optimizer.update, in_axes=(0, 0, 0, None))(g_bcast, opt_state, W, lr)
        metrics = {"loss": jnp.mean(loss),
                   **{k: jnp.mean(v) for k, v in aux.items()}}
        return new_W, new_state, metrics

    return step


def group_sync(W: Pytree, group_size: int) -> Pytree:
    """Hierarchical (beyond-paper): average only within contiguous groups of
    ``group_size`` replicas (= one pod).  Cross-group averaging is left to
    the outer adaptive schedule."""
    def leaf(x):
        R = x.shape[0]
        g = x.reshape(R // group_size, group_size, *x.shape[1:])
        m = jnp.mean(g.astype(jnp.float32), axis=1, keepdims=True)
        return jnp.broadcast_to(m, g.shape).reshape(x.shape).astype(x.dtype)
    return jax.tree_util.tree_map(leaf, W)
