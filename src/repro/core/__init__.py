from repro.core.averaging import (  # noqa: F401
    stack_replicas, replica_mean, parameter_variance, sync_replicas,
    make_local_step, make_full_step, group_sync, n_replicas,
)
from repro.core.controller import (  # noqa: F401
    ADPSGDController, ConstantPeriodController, FullSyncController,
    DecreasingPeriodController, HierarchicalADPSGDController, make_controller,
)
