"""QSGD baseline (Alistarh et al. 2017) — stochastic gradient quantization.

The paper compares ADPSGD against 8-bit QSGD (§IV: "QSGD uses 8 bits to
store each gradient component, its communication data size is 1/4 of
FULLSGD and 2x of our ADPSGD").  Every iteration each replica quantizes its
gradient, "transmits" it (simulated: quantize→dequantize round-trip), and
all replicas apply the averaged dequantized gradient — trajectories stay
identical, as with a parameter server.

``quantize``/``dequantize`` reference implementations live here; the
bandwidth-bound inner loop has a Pallas kernel (repro/kernels/qsgd_quant.py).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.optim.optimizers import Optimizer

Pytree = Any


def quantize(v: jnp.ndarray, key, bits: int = 8) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """QSGD stochastic quantization of one tensor.

    q_i = ||v||₂ · sgn(v_i) · ξ_i / s  with s = 2^(bits−1) − 1 levels and
    ξ_i ∈ {⌊|v_i|·s/‖v‖⌋, ⌈…⌉} chosen stochastically so E[q] = v.
    Returns (levels int8, norm scalar f32).
    """
    s = (1 << (bits - 1)) - 1
    vf = v.astype(jnp.float32)
    norm = jnp.sqrt(jnp.sum(jnp.square(vf)))
    scaled = jnp.where(norm > 0, jnp.abs(vf) / norm * s, 0.0)
    floor = jnp.floor(scaled)
    prob = scaled - floor
    rnd = jax.random.uniform(key, v.shape)
    mag = floor + (rnd < prob).astype(jnp.float32)
    levels = (jnp.sign(vf) * mag).astype(jnp.int8)
    return levels, norm


def dequantize(levels: jnp.ndarray, norm: jnp.ndarray, bits: int = 8,
               dtype=jnp.float32) -> jnp.ndarray:
    s = (1 << (bits - 1)) - 1
    return (levels.astype(jnp.float32) * (norm / s)).astype(dtype)


def replica_keys(key, idx):
    """Per-replica RNG keys: ``fold_in`` on the *global* replica index.
    The single definition every backend shares — cross-backend/placement
    parity of the quantization noise depends on these streams matching
    bit-for-bit, so never derive per-replica keys any other way."""
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(idx)


def quantize_pytree(grads: Pytree, key, bits: int = 8) -> Pytree:
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, g in zip(keys, leaves):
        lv, nm = quantize(g, k, bits)
        out.append(dequantize(lv, nm, bits, g.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def quantize_split_pytree(grads: Pytree, key, bits: int = 8, *,
                          use_kernel: bool = False) -> Tuple[Pytree, Pytree]:
    """The byte-true wire representation: quantize every leaf but keep the
    payload split as (int8 levels tree, f32 per-tensor norms tree) instead
    of fusing the dequantize — this pair is what a byte-true exchange puts
    on the wire (``backends/ops.qsgd_wire``); the receiver dequantizes via
    ``dequantize_split_pytree``.  The RNG stream (one split per leaf, same
    uniforms) matches ``quantize_pytree`` exactly, so split+dequantize is
    bit-identical to the fused round-trip.  ``use_kernel`` routes the
    bandwidth-bound inner loop through the Pallas kernels
    (``kernels/qsgd_quant.py``) — profitable on TPU only."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    keys = jax.random.split(key, len(leaves))
    lvs, nms = [], []
    for k, g in zip(keys, leaves):
        if use_kernel:
            from repro.kernels import qsgd_quant
            u = jax.random.uniform(k, g.shape)
            lv, nm = qsgd_quant.quantize(g.astype(jnp.float32), u, bits=bits)
        else:
            lv, nm = quantize(g, k, bits)
        lvs.append(lv)
        nms.append(nm)
    return (jax.tree_util.tree_unflatten(treedef, lvs),
            jax.tree_util.tree_unflatten(treedef, nms))


def dequantize_split_pytree(levels: Pytree, norms: Pytree, bits: int = 8,
                            dtype=jnp.float32) -> Pytree:
    """Receiver side of the byte-true exchange.  Norm leaves may carry
    leading batch dims (a stacked replica axis from an all-gather) — they
    broadcast against the matching level leaves."""
    s = (1 << (bits - 1)) - 1

    def leaf(lv, nm):
        nm = nm.reshape(nm.shape + (1,) * (lv.ndim - nm.ndim))
        return (lv.astype(jnp.float32) * (nm / s)).astype(dtype)

    return jax.tree_util.tree_map(leaf, levels, norms)


def make_qsgd_step(loss_fn, optimizer: Optimizer, bits: int = 8):
    """Full-communication step with quantized gradients.  Signature matches
    the other steps plus an rng key: step(W, opt, batch, lr, key)."""
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(W, opt_state, batch, lr, key):
        (loss, aux), grads = jax.vmap(grad_fn)(W, batch)
        R = jax.tree_util.tree_leaves(W)[0].shape[0]
        keys = replica_keys(key, jnp.arange(R))
        q = jax.vmap(lambda g, k: quantize_pytree(g, k, bits))(grads, keys)
        g_mean = jax.tree_util.tree_map(
            lambda g: jnp.mean(g.astype(jnp.float32), axis=0, keepdims=True),
            q)
        g_bcast = jax.tree_util.tree_map(
            lambda g, w: jnp.broadcast_to(g, w.shape).astype(w.dtype), g_mean, W)
        new_W, new_state = jax.vmap(
            optimizer.update, in_axes=(0, 0, 0, None))(g_bcast, opt_state, W, lr)
        metrics = {"loss": jnp.mean(loss),
                   **{k: jnp.mean(v) for k, v in aux.items()}}
        return new_W, new_state, metrics

    return step
