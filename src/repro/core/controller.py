"""Host-side averaging-period controllers.

The controller decides, each iteration, whether the next dispatched program
is the (collective-free) local step or the sync step — and adapts the period
from the measured variance probe S_k.  This is Algorithm 2 of the paper plus
the baselines it compares against.  Controllers are plain python: both
programs are pre-compiled and dispatch is asynchronous, so the control
decision is off the critical path (DESIGN.md §2).
"""
from __future__ import annotations

import math
from typing import List, Optional

from repro.configs.base import AveragingConfig


class PeriodController:
    """Base: call ``sync_now(k)`` once per iteration k; if it returns True,
    run the sync program and feed the measured S_k back via
    ``observe(k, lr, S_k)``."""

    name = "base"

    def __init__(self, cfg: AveragingConfig, total_steps: int):
        self.cfg = cfg
        self.total_steps = total_steps
        self.cnt = 0
        self.sync_steps: List[int] = []
        self.period_history: List[int] = []

    @property
    def period(self) -> int:
        raise NotImplementedError

    def sync_now(self, k: int) -> bool:
        if k < self.cfg.warmup_full_sync_steps:
            self._record(k)
            return True
        self.cnt += 1
        if self.cnt >= self.period:
            self.cnt = 0
            self._record(k)
            return True
        return False

    def _record(self, k: int):
        self.sync_steps.append(k)
        self.period_history.append(self.period)

    def observe(self, k: int, lr: float, s_k: float) -> None:
        pass

    @property
    def n_syncs(self) -> int:
        return len(self.sync_steps)

    def mean_period(self, total_steps: Optional[int] = None) -> float:
        t = total_steps or self.total_steps
        return t / max(1, self.n_syncs)

    # Adaptive state for checkpoint/resume: restoring must continue the
    # identical sync schedule (Algorithm 2 is stateful across syncs).
    _STATE_ATTRS = ("cnt",)

    def state_dict(self) -> dict:
        return {a: getattr(self, a) for a in self._STATE_ATTRS
                if hasattr(self, a)}

    def load_state_dict(self, state: dict) -> None:
        for a in self._STATE_ATTRS:
            if a in state and hasattr(self, a):
                setattr(self, a, state[a])


class FullSyncController(PeriodController):
    """FULLSGD: synchronize every iteration (p = 1)."""

    name = "fullsgd"

    @property
    def period(self) -> int:
        return 1


class ConstantPeriodController(PeriodController):
    """CPSGD (Algorithm 1): constant period p."""

    name = "cpsgd"

    @property
    def period(self) -> int:
        return self.cfg.p_const


class DecreasingPeriodController(PeriodController):
    """Wang & Joshi's decreasing schedule (paper §V-B — shown harmful):
    period p0 for the first half of training, p1 afterwards."""

    name = "decreasing"

    def __init__(self, cfg: AveragingConfig, total_steps: int):
        super().__init__(cfg, total_steps)
        self.switch = total_steps // 2
        self._k = 0

    def sync_now(self, k: int) -> bool:
        self._k = k
        return super().sync_now(k)

    @property
    def period(self) -> int:
        return self.cfg.decreasing_p0 if self._k < self.switch \
            else self.cfg.decreasing_p1


class ADPSGDController(PeriodController):
    """Algorithm 2 — the paper's contribution.

    * iterations < warmup_full_sync_steps: period 1 (paper: first epoch).
    * first K_s iterations: period = p_init while sampling
      C2 = RunningAverage(S_k / γ_k) at each sync (line 14).
    * afterwards: p += 1 when S_k < 0.7·γ_k·C2, p −= 1 when
      S_k > 1.3·γ_k·C2 (lines 16–19): keeps the pre-sync parameter variance
      pinned proportional to the learning rate (Eq. 16) — the condition that
      preserves the O(1/√(MK)) rate with the least communication.
    """

    name = "adpsgd"
    _STATE_ATTRS = ("cnt", "p", "c2", "n_c2")

    def __init__(self, cfg: AveragingConfig, total_steps: int):
        super().__init__(cfg, total_steps)
        self.p = cfg.p_init
        self.c2 = 0.0
        self.n_c2 = 0
        self.k_sample = int(cfg.k_sample_frac * total_steps)

    @property
    def period(self) -> int:
        return self.p

    def observe(self, k: int, lr: float, s_k: float) -> None:
        if k < self.cfg.warmup_full_sync_steps:
            return
        if k < self.k_sample:
            self.n_c2 += 1
            self.c2 += (s_k / max(lr, 1e-12) - self.c2) / self.n_c2
            return
        if self.n_c2 == 0:      # degenerate: no sampling window
            self.n_c2 = 1
            self.c2 = s_k / max(lr, 1e-12)
            return
        target = lr * self.c2
        if s_k < self.cfg.lower * target:
            self.p = min(self.p + 1, self.cfg.p_max)
        elif s_k > self.cfg.upper * target:
            self.p = max(self.p - 1, self.cfg.p_min)


class AdaCommController(PeriodController):
    """Wang & Joshi's AdaComm (arXiv:1810.08313, Alg. 2): the best
    error-runtime trade-off starts with infrequent communication and tightens
    it as the loss falls.  Training is cut into blocks of
    ``adacomm_interval`` iterations; at each block boundary the period is
    reset to

        tau_j = ceil( tau_0 * sqrt( F(w_j) / F(w_0) ) )

    where F is the running training loss of the block just finished and
    F(w_0) the first block's (the calibration block keeps tau_0 = p_init).
    The loss feedback arrives through ``observe_loss`` — per-step losses the
    engine already reads back for its history, so the schedule costs no
    extra device round-trips."""

    name = "adacomm"
    _STATE_ATTRS = ("cnt", "tau", "f0", "_loss_sum", "_loss_n")

    def __init__(self, cfg: AveragingConfig, total_steps: int):
        super().__init__(cfg, total_steps)
        self.tau0 = max(1, cfg.p_init)
        self.tau = self.tau0
        self.interval = max(1, cfg.adacomm_interval)
        self.f0: Optional[float] = None
        self._loss_sum = 0.0
        self._loss_n = 0

    @property
    def period(self) -> int:
        return self.tau

    def observe_loss(self, k: int, loss: float) -> None:
        # lazy accumulation: when the engine defers loss read-back (the
        # sampled WallClock's async pipeline), ``loss`` is a device scalar
        # and the sum stays on device — the host converts only at block
        # boundaries.  With ordinary floats this is the same f64 sum as
        # always (bit-exact schedules preserved).  In deferred mode the
        # sum accumulates in device f32, so block means may differ in the
        # low bits from the host path — acceptable: that mode exists only
        # under a real WallClock, whose schedule is wall-time-dependent
        # and was never reproducible to begin with.
        self._loss_sum = self._loss_sum + loss
        self._loss_n += 1
        if (k + 1) % self.interval == 0 and self._loss_n:
            f = float(self._loss_sum) / self._loss_n
            if self.f0 is None:
                self.f0 = f                     # calibration block
            else:
                self.tau = int(min(max(
                    math.ceil(self.tau0 * math.sqrt(max(f, 0.0) / self.f0)),
                    self.cfg.p_min), self.cfg.p_max))
            self._loss_sum = 0.0
            self._loss_n = 0

    def state_dict(self) -> dict:
        # the running sum may be a device scalar (deferred read-back);
        # checkpoints need plain json-serializable state
        self._loss_sum = float(self._loss_sum)
        return super().state_dict()


class AdaCommTimeController(AdaCommController):
    """AdaComm's *wall-clock* form (arXiv:1810.08313 §4): the paper defines
    the adaptation block in **seconds** (t0), not iterations — every t0
    seconds of (measured or simulated) run time the period is recomputed
    from the block's average loss, ``tau = ceil(tau0 * sqrt(F/F0))``.  On a
    slow network each sync costs more wall-clock, so fewer iterations fit a
    block and the boundary sees a higher loss — the controller holds a
    larger period exactly when communication is expensive, which is the
    paper's 10-vs-100 Gbps behavior.

    Straggler rescaling: with a straggler slowdown s (the block waits for
    the slowest replica), per-round wall time is ``tau*s*t_step + t_comm``,
    so the error-runtime-optimal period ``tau* ∝ sqrt(t_comm/(s*t_step))``
    shrinks by ``sqrt(s)`` — the controller divides the loss-derived period
    by ``sqrt(clock.straggler_factor())``.

    Time comes from the engine's ``runtime/clock.py`` Clock (bound via
    ``bind_clock``); under a ``SimulatedClock`` the whole schedule is
    bit-reproducible on CPU CI.  ``_block_start`` is stored in clock
    coordinates, so checkpoint/resume continues the same schedule
    *mid-block* — provided the clock state is restored alongside
    (``checkpoint/io.py`` carries it next to the strategy state)."""

    name = "adacomm_time"
    _STATE_ATTRS = ("cnt", "tau", "f0", "_loss_sum", "_loss_n",
                    "_block_start")

    def __init__(self, cfg: AveragingConfig, total_steps: int):
        super().__init__(cfg, total_steps)
        self.t0 = float(cfg.adacomm_t0)
        self.clock = None
        self._block_start: Optional[float] = None

    def bind_clock(self, clock) -> None:
        if clock is None:
            raise ValueError(
                "adacomm_mode='time' adapts per wall-clock block and needs "
                "a Clock: pass clock= to TrainerEngine (--net on the "
                "driver, e.g. --net 10gbps or --net real)")
        self.clock = clock

    def observe_loss(self, k: int, loss: float) -> None:
        self._loss_sum = self._loss_sum + loss   # lazy (see AdaComm above)
        self._loss_n += 1
        now = self.clock.now()
        if self._block_start is None:
            self._block_start = now
        if now - self._block_start < self.t0:
            return
        f = float(self._loss_sum) / self._loss_n
        if self.f0 is None:
            self.f0 = f                         # calibration block
        else:
            s = max(1.0, float(self.clock.straggler_factor()))
            tau = math.ceil(self.tau0 * math.sqrt(max(f, 0.0) / self.f0)
                            / math.sqrt(s))
            self.tau = int(min(max(tau, self.cfg.p_min), self.cfg.p_max))
        self._loss_sum = 0.0
        self._loss_n = 0
        self._block_start = now


class HierarchicalADPSGDController(ADPSGDController):
    """Beyond-paper: two-level schedule for multi-pod meshes.  The inner
    (in-pod, fast ICI) sync runs at a small constant period ``inner_period``;
    the outer (cross-pod, slow link) sync is the adaptive one.  ``sync_now``
    refers to the *outer* sync; query ``inner_sync_now`` separately."""

    name = "hier_adpsgd"
    _STATE_ATTRS = ("cnt", "p", "c2", "n_c2", "_inner_cnt")

    def __init__(self, cfg: AveragingConfig, total_steps: int,
                 inner_period: Optional[int] = None):
        super().__init__(cfg, total_steps)
        if inner_period is None:
            inner_period = getattr(cfg, "inner_period", 1)
        self.inner_period = max(1, inner_period)
        self._inner_cnt = 0
        self.inner_sync_steps: List[int] = []

    def inner_sync_now(self, k: int) -> bool:
        self._inner_cnt += 1
        if self._inner_cnt >= self.inner_period:
            self._inner_cnt = 0
            self.inner_sync_steps.append(k)
            return True
        return False

    def reset_inner(self) -> None:
        """Restart the in-group drift clock (an outer sync equalizes every
        group, subsuming the pending inner sync)."""
        self._inner_cnt = 0


def make_controller(cfg: AveragingConfig, total_steps: int) -> PeriodController:
    """Controller for ``cfg.method``, resolved through the strategy
    registry's ``controller_cls`` (the single source of truth; late import
    because strategies import this module).  Every-step strategies declare
    no controller — legacy callers get the period-1 FullSyncController the
    seed loop provided."""
    from repro.strategies import get_strategy_cls
    cls = getattr(get_strategy_cls(cfg.method), "controller_cls", None)
    if cls is None:
        cls = FullSyncController
    return cls(cfg, total_steps)
