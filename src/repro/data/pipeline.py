"""Deterministic synthetic data pipelines.

Both pipelines are *learnable* (structured, not pure noise) so convergence
comparisons between averaging methods are meaningful, and both reproduce the
paper's data handling: a fixed dataset, globally shuffled each epoch, then
sharded across replicas (paper §IV-A: "training data ... globally shuffled
at the end of each epoch").
"""
from __future__ import annotations

from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np


class SyntheticImages:
    """CIFAR-10-shaped classification data: class prototypes + noise.
    Stands in for the paper's CIFAR-10 experiments."""

    def __init__(self, n_samples: int = 4096, n_classes: int = 10,
                 noise: float = 0.6, seed: int = 0):
        rng = np.random.RandomState(seed)
        self.protos = rng.randn(n_classes, 32, 32, 3).astype(np.float32)
        self.labels = rng.randint(0, n_classes, size=n_samples).astype(np.int32)
        self.images = (self.protos[self.labels]
                       + noise * rng.randn(n_samples, 32, 32, 3)).astype(np.float32)
        self.n = n_samples
        self.seed = seed

    def batches(self, *, n_replicas: int, per_replica_batch: int,
                ) -> "EpochSharder":
        return EpochSharder(
            {"images": self.images, "labels": self.labels},
            self.n, n_replicas, per_replica_batch, self.seed)

    def eval_batches(self, batch: int = 256):
        for i in range(0, self.n, batch):
            yield {"images": jnp.asarray(self.images[i:i + batch]),
                   "labels": jnp.asarray(self.labels[i:i + batch])}


class SyntheticTokens:
    """LM data from a learnable stochastic process: token_{t+1} =
    (a·token_t + c) mod V with probability 1−ε, uniform otherwise."""

    def __init__(self, vocab_size: int, seq_len: int, n_samples: int = 2048,
                 eps: float = 0.1, seed: int = 0):
        rng = np.random.RandomState(seed + 1)
        a, c = 31, 17
        toks = np.zeros((n_samples, seq_len), np.int32)
        toks[:, 0] = rng.randint(0, vocab_size, n_samples)
        for t in range(1, seq_len):
            det = (a * toks[:, t - 1] + c) % vocab_size
            rand = rng.randint(0, vocab_size, n_samples)
            toks[:, t] = np.where(rng.rand(n_samples) < eps, rand, det)
        self.tokens = toks
        self.n = n_samples
        self.seed = seed

    def batches(self, *, n_replicas: int, per_replica_batch: int):
        return EpochSharder({"tokens": self.tokens}, self.n, n_replicas,
                            per_replica_batch, self.seed)

    def eval_batches(self, batch: int = 64, limit: int = 512):
        for i in range(0, min(self.n, limit), batch):
            yield {"tokens": jnp.asarray(self.tokens[i:i + batch])}


class EpochSharder:
    """step -> batch dict with a leading replica axis (R, b, ...).  Each
    epoch reshuffles globally with a deterministic per-epoch seed."""

    def __init__(self, arrays: Dict[str, np.ndarray], n: int,
                 n_replicas: int, per_replica_batch: int, seed: int):
        self.arrays = arrays
        self.n = n
        self.R = n_replicas
        self.b = per_replica_batch
        self.global_b = n_replicas * per_replica_batch
        self.steps_per_epoch = max(1, n // self.global_b)
        self.seed = seed
        self._epoch = -1
        self._perm: Optional[np.ndarray] = None

    def __call__(self, step: int) -> Dict[str, jnp.ndarray]:
        epoch, within = divmod(step, self.steps_per_epoch)
        if epoch != self._epoch:
            self._perm = np.random.RandomState(
                self.seed + 1000 + epoch).permutation(self.n)
            self._epoch = epoch
        idx = self._perm[within * self.global_b:(within + 1) * self.global_b]
        out = {}
        for k, v in self.arrays.items():
            x = v[idx]
            out[k] = jnp.asarray(x.reshape(self.R, self.b, *v.shape[1:]))
        return out
