"""Pytree checkpointing to .npz + controller/loop state to json.

The controller's adaptive state (p, C2, cnt) is part of the training state —
restoring a run must resume the same period schedule (Algorithm 2 is
stateful across syncs).

Checkpoints are backend-neutral: arrays are ``jax.device_get`` to host
numpy before writing (gathering sharded arrays off a mesh), and the engine
re-``put``s them through whatever ``ExecutionBackend`` the restoring run
uses — a vmap-saved checkpoint resumes on a mesh and vice versa.  Strategy
state may carry device pytrees under the ``_arrays`` key; those go to
``strategy_arrays.npz`` next to the json meta.  This includes *in-flight
overlap-op state*: when DaSGD checkpoints mid-overlap (snapshot
dispatched, correction not yet applied), its ``state_dict`` fetches the
``InFlightOp`` — a checkpoint is a synchronization point — and rides the
pending delta, its variance probe and the due/snapshot steps here, so the
resumed run applies the identical correction at the identical iteration
and reports the identical probe (resume is exact, not approximate)."""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

Pytree = Any
SEP = "|"


def _flatten(tree: Pytree, prefix: str = "") -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{SEP}{k}" if prefix else str(k)))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{SEP}#{i}" if prefix else f"#{i}"))
    else:
        out[prefix] = np.asarray(tree)
    return out


def _unflatten(flat: Dict[str, np.ndarray]) -> Pytree:
    root: Dict[str, Any] = {}
    for path, v in flat.items():
        parts = path.split(SEP)
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v

    def rebuild(node):
        if not isinstance(node, dict):
            return jax.numpy.asarray(node)
        if node and all(k.startswith("#") for k in node):
            return [rebuild(node[f"#{i}"]) for i in range(len(node))]
        return {k: rebuild(v) for k, v in node.items()}

    return rebuild(root)


def save_checkpoint(path: str, params: Pytree, *,
                    opt_state: Optional[Pytree] = None,
                    step: int = 0,
                    controller_state: Optional[Dict] = None,
                    clock_state: Optional[Dict] = None) -> None:
    os.makedirs(path, exist_ok=True)
    np.savez(os.path.join(path, "params.npz"),
             **_flatten(jax.device_get(params)))
    if opt_state is not None:
        np.savez(os.path.join(path, "opt_state.npz"),
                 **_flatten(jax.device_get(opt_state)))
    state = dict(controller_state or {})
    arrays = state.pop("_arrays", None)
    arr_path = os.path.join(path, "strategy_arrays.npz")
    if arrays:
        np.savez(arr_path, **_flatten(jax.device_get(arrays)))
    elif os.path.exists(arr_path):
        os.remove(arr_path)            # don't resurrect a stale anchor
    meta = {"step": step, "controller": state}
    if clock_state is not None:
        # telemetry-clock state (runtime/clock.py): time-driven schedules
        # (wall-clock AdaComm) must resume the same t0-block mid-block, so
        # the clock's coordinates are training state like the controller's
        meta["clock"] = clock_state
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump(meta, f)


def load_checkpoint(path: str) -> Tuple[Pytree, Optional[Pytree], Dict]:
    with np.load(os.path.join(path, "params.npz")) as z:
        params = _unflatten({k: z[k] for k in z.files})
    opt_state = None
    opt_path = os.path.join(path, "opt_state.npz")
    if os.path.exists(opt_path):
        with np.load(opt_path) as z:
            opt_state = _unflatten({k: z[k] for k in z.files})
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    arr_path = os.path.join(path, "strategy_arrays.npz")
    if os.path.exists(arr_path):
        with np.load(arr_path) as z:
            meta.setdefault("controller", {})["_arrays"] = _unflatten(
                {k: z[k] for k in z.files})
    return params, opt_state, meta


def controller_state(ctrl) -> Dict:
    d = {"n_syncs": ctrl.n_syncs}
    d.update(ctrl.state_dict())
    return d


def restore_controller(ctrl, state: Dict) -> None:
    ctrl.load_state_dict(state)


def strategy_state(strategy) -> Dict:
    """Serializable adaptive state of a ``CommunicationStrategy`` (includes
    its controller's Algorithm-2 state, if any)."""
    d = {"strategy": strategy.name}
    d.update(strategy.state_dict())
    return d


def restore_strategy(strategy, state: Dict) -> None:
    """Restore ``strategy_state`` into a fresh strategy: the resumed run
    must continue the identical sync schedule."""
    saved = state.get("strategy")
    if saved and saved != strategy.name:
        raise ValueError(
            f"checkpoint holds state for strategy '{saved}', "
            f"got '{strategy.name}'")
    strategy.load_state_dict(state)
