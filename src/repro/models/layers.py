"""Core neural-net layers in pure JAX.

Everything is functional: ``init_*`` builds a param pytree (nested dicts of
jnp arrays), ``*_forward`` consumes it.  All layers support both full-sequence
(train / prefill) and single-token cached decode.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

Params = Dict[str, Any]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def dense_init(key, d_in: int, d_out: int, *, dtype, bias: bool = False,
               scale: float | None = None) -> Params:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(key, cfg: ModelConfig, d: int) -> Params:
    dt = _dtype(cfg)
    if cfg.norm_type == "rmsnorm":
        return {"scale": jnp.ones((d,), dt)}
    if cfg.norm_type == "layernorm":
        return {"scale": jnp.ones((d,), dt), "bias": jnp.zeros((d,), dt)}
    if cfg.norm_type == "nonparametric_ln":   # OLMo
        return {}
    raise ValueError(cfg.norm_type)


def norm_forward(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    eps = cfg.norm_eps
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mean), axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    if cfg.norm_type == "layernorm":
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard, partial, and M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(rotary_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, rotary_dim, 2, dtype=jnp.float32) / rotary_dim))


def apply_rope(x: jnp.ndarray, pos: jnp.ndarray, theta: float,
               rotary_frac: float = 1.0) -> jnp.ndarray:
    """x: (B,S,H,dh); pos: (B,S) int32.  Rotates the first
    ``rotary_frac * dh`` dims (half-split convention)."""
    dh = x.shape[-1]
    rd = int(dh * rotary_frac)
    rd -= rd % 2
    inv = rope_freqs(rd, theta)                           # (rd/2,)
    ang = pos[..., None].astype(jnp.float32) * inv        # (B,S,rd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    xr, xp = x[..., :rd], x[..., rd:]
    x1, x2 = xr[..., : rd // 2], xr[..., rd // 2:]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    return jnp.concatenate([out1.astype(x.dtype), out2.astype(x.dtype), xp], axis=-1)


def apply_mrope(x: jnp.ndarray, pos3: jnp.ndarray, theta: float,
                sections: Tuple[int, int, int]) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE.  pos3: (3,B,S) — temporal/height/width
    position ids.  ``sections`` partitions the dh/2 frequency slots."""
    dh = x.shape[-1]
    assert sum(sections) == dh // 2, (sections, dh)
    inv = rope_freqs(dh, theta)                           # (dh/2,)
    ang = pos3[..., None].astype(jnp.float32) * inv       # (3,B,S,dh/2)
    # pick which of t/h/w drives each frequency slot
    sel = jnp.repeat(jnp.arange(3), jnp.array(sections),
                     total_repeat_length=dh // 2)         # (dh/2,)
    ang = jnp.einsum("tbsf,tf->bsf", ang, jax.nn.one_hot(sel, 3).T)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., : dh // 2], x[..., dh // 2:]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    return jnp.concatenate([out1.astype(x.dtype), out2.astype(x.dtype)], axis=-1)


def sinusoidal_embedding(n_pos: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(n_pos, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Attention (GQA / SWA / cross / MLA) with optional KV cache
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig) -> Params:
    dt = _dtype(cfg)
    D, H, K, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim()
    ks = jax.random.split(key, 6)
    if cfg.attention_type == "mla":
        m: MLAConfig = cfg.mla
        qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
        p = {
            "wq": dense_init(ks[0], D, H * qk_dim, dtype=dt),
            "wkv_a": dense_init(ks[1], D, m.kv_lora_rank + m.qk_rope_head_dim, dtype=dt),
            "kv_norm": {"scale": jnp.ones((m.kv_lora_rank,), dt)},
            "wkv_b": dense_init(ks[2], m.kv_lora_rank,
                                H * (m.qk_nope_head_dim + m.v_head_dim), dtype=dt),
            "wo": dense_init(ks[3], H * m.v_head_dim, D, dtype=dt),
        }
        if m.q_lora_rank:
            p["wq_a"] = dense_init(ks[4], D, m.q_lora_rank, dtype=dt)
            p["q_norm"] = {"scale": jnp.ones((m.q_lora_rank,), dt)}
            p["wq"] = dense_init(ks[0], m.q_lora_rank, H * qk_dim, dtype=dt)
        return p
    b = cfg.attn_qkv_bias
    return {
        "wq": dense_init(ks[0], D, H * dh, dtype=dt, bias=b),
        "wk": dense_init(ks[1], D, K * dh, dtype=dt, bias=b),
        "wv": dense_init(ks[2], D, K * dh, dtype=dt, bias=b),
        "wo": dense_init(ks[3], H * dh, D, dtype=dt),
    }


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16) -> Params:
    """Fixed-size ring buffer.  For SWA the buffer is only ``window`` long."""
    if cfg.attention_type == "mla":
        m = cfg.mla
        return {
            "ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
            "kpe": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
            "pos": jnp.full((batch, max_len), -1, jnp.int32),
        }
    buf = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    K, dh = cfg.n_kv_heads, cfg.head_dim()
    return {
        "k": jnp.zeros((batch, buf, K, dh), dtype),
        "v": jnp.zeros((batch, buf, K, dh), dtype),
        "pos": jnp.full((batch, buf), -1, jnp.int32),
    }


def _sdpa(q, k, v, mask, softcap: float = 0.0):
    """q:(B,Sq,H,dh) k,v:(B,Sk,K,dv) grouped-query attention core."""
    B, Sq, H, dh = q.shape
    Kh = k.shape[2]
    G = H // Kh
    q = q.reshape(B, Sq, Kh, G, dh)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(dh)
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, v.shape[-1]).astype(v.dtype)


def _causal_mask(q_pos: jnp.ndarray, k_pos: jnp.ndarray, window: int) -> jnp.ndarray:
    """q_pos (B,Sq), k_pos (B,Sk) -> (B,Sq,Sk) bool."""
    m = k_pos[:, None, :] <= q_pos[:, :, None]
    m &= k_pos[:, None, :] >= 0
    if window:
        m &= k_pos[:, None, :] > q_pos[:, :, None] - window
    return m


def attention_forward(p: Params, x: jnp.ndarray, cfg: ModelConfig, *,
                      positions: jnp.ndarray,
                      cache: Optional[Params] = None,
                      cache_index: Optional[jnp.ndarray] = None,
                      cross_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
                      mrope_pos: Optional[jnp.ndarray] = None,
                      ) -> Tuple[jnp.ndarray, Optional[Params]]:
    """Returns (output, updated_cache).

    * full-sequence: cache=None — causal (or cross) attention over x.
    * decode: cache given, x is (B,1,D), cache_index is the write slot.
    """
    if cfg.attention_type == "mla":
        return _mla_forward(p, x, cfg, positions=positions, cache=cache,
                            cache_index=cache_index)
    B, S, D = x.shape
    H, Kh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim()
    q = dense(p["wq"], x).reshape(B, S, H, dh)
    if cross_kv is not None:
        k, v = cross_kv
        mask = jnp.ones((B, S, k.shape[1]), bool)
        out = _sdpa(q, k, v, mask, cfg.attn_logit_softcap)
        return dense(p["wo"], out.reshape(B, S, H * dh)), cache
    k = dense(p["wk"], x).reshape(B, S, Kh, dh)
    v = dense(p["wv"], x).reshape(B, S, Kh, dh)
    if cfg.pos_type == "mrope":
        q = apply_mrope(q, mrope_pos, cfg.rope_theta, cfg.vision.mrope_sections)
        k = apply_mrope(k, mrope_pos, cfg.rope_theta, cfg.vision.mrope_sections)
    elif cfg.pos_type == "rope":
        q = apply_rope(q, positions, cfg.rope_theta, cfg.partial_rotary_factor)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.partial_rotary_factor)

    if cache is None:
        if cfg.use_flash and cfg.sliding_window == 0 and S > 1:
            from repro.kernels import ops as kops
            out = kops.flash_attention(q, k, v, causal=True)
        else:
            mask = _causal_mask(positions, positions, cfg.sliding_window)
            out = _sdpa(q, k, v, mask, cfg.attn_logit_softcap)
        return dense(p["wo"], out.reshape(B, S, H * dh)), None

    # --- cached decode (S == 1) ---
    buf = cache["k"].shape[1]
    slot = (cache_index % buf).astype(jnp.int32)
    k_cache = _scatter_rows(cache["k"], k, slot)
    v_cache = _scatter_rows(cache["v"], v, slot)
    pos_cache = _scatter_pos(cache["pos"], positions, slot)
    mask = _causal_mask(positions, pos_cache, cfg.sliding_window)
    out = _sdpa(q, k_cache, v_cache, mask, cfg.attn_logit_softcap)
    new_cache = {"k": k_cache, "v": v_cache, "pos": pos_cache}
    return dense(p["wo"], out.reshape(B, S, H * dh)), new_cache


def _scatter_rows(buf: jnp.ndarray, x: jnp.ndarray, slot: jnp.ndarray) -> jnp.ndarray:
    """Write x (B,1,...) into buf (B,S,...) at per-batch-uniform slot."""
    return jax.lax.dynamic_update_slice(
        buf, x.astype(buf.dtype), (0, slot) + (0,) * (buf.ndim - 2))


def _scatter_pos(buf: jnp.ndarray, pos: jnp.ndarray, slot: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.dynamic_update_slice(buf, pos.astype(buf.dtype), (0, slot))


def _mla_forward(p: Params, x: jnp.ndarray, cfg: ModelConfig, *,
                 positions, cache=None, cache_index=None):
    """DeepSeek-V2 multi-head latent attention.  The KV cache stores only
    the compressed latent (kv_lora_rank) + shared rope key — the paper's
    beyond-baseline memory win for decode."""
    m: MLAConfig = cfg.mla
    B, S, D = x.shape
    H = cfg.n_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    if m.q_lora_rank:
        cq = dense(p["wq_a"], x)
        cq = _rms(cq, p["q_norm"]["scale"], cfg.norm_eps)
        q = dense(p["wq"], cq).reshape(B, S, H, qk_dim)
    else:
        q = dense(p["wq"], x).reshape(B, S, H, qk_dim)
    qn, qr = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    qr = apply_rope(qr, positions, cfg.rope_theta)

    kv_a = dense(p["wkv_a"], x)
    ckv, kpe = kv_a[..., : m.kv_lora_rank], kv_a[..., m.kv_lora_rank:]
    ckv = _rms(ckv, p["kv_norm"]["scale"], cfg.norm_eps)
    kpe = apply_rope(kpe[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]

    if cache is not None:
        slot = cache_index.astype(jnp.int32)
        ckv = jax.lax.dynamic_update_slice(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, slot, 0))
        kpe = jax.lax.dynamic_update_slice(
            cache["kpe"], kpe.astype(cache["kpe"].dtype), (0, slot, 0))
        pos_cache = _scatter_pos(cache["pos"], positions, slot)
        new_cache = {"ckv": ckv, "kpe": kpe, "pos": pos_cache}
        k_pos = pos_cache
    else:
        new_cache = None
        k_pos = positions

    kv = dense(p["wkv_b"], ckv.astype(x.dtype))
    Sk = kv.shape[1]
    kv = kv.reshape(B, Sk, H, m.qk_nope_head_dim + m.v_head_dim)
    kn, v = kv[..., : m.qk_nope_head_dim], kv[..., m.qk_nope_head_dim:]
    k = jnp.concatenate([kn, jnp.broadcast_to(kpe[:, :, None, :].astype(x.dtype),
                                              (B, Sk, H, m.qk_rope_head_dim))], axis=-1)
    q_full = jnp.concatenate([qn, qr], axis=-1)
    mask = _causal_mask(positions, k_pos, 0)
    out = _sdpa(q_full, k, v, mask, cfg.attn_logit_softcap)
    return dense(p["wo"], out.reshape(B, S, H * m.v_head_dim)), new_cache


def _rms(x, scale, eps):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    dt = _dtype(cfg)
    D, F = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_type == "swiglu":
        return {
            "w_gate": dense_init(ks[0], D, F, dtype=dt),
            "w_up": dense_init(ks[1], D, F, dtype=dt),
            "w_down": dense_init(ks[2], F, D, dtype=dt),
        }
    return {  # gelu (whisper)
        "w_up": dense_init(ks[0], D, F, dtype=dt, bias=True),
        "w_down": dense_init(ks[1], F, D, dtype=dt, bias=True),
    }


def mlp_forward(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    if "w_gate" in p:
        return dense(p["w_down"], jax.nn.silu(dense(p["w_gate"], x)) * dense(p["w_up"], x))
    return dense(p["w_down"], jax.nn.gelu(dense(p["w_up"], x)))


# ---------------------------------------------------------------------------
# Mixture of Experts (GShard-style grouped einsum dispatch)
# ---------------------------------------------------------------------------


def init_moe(key, cfg: ModelConfig) -> Params:
    m: MoEConfig = cfg.moe
    dt = _dtype(cfg)
    D, F, E = cfg.d_model, m.d_ff_expert, m.n_experts
    ks = jax.random.split(key, 5)
    s = 1.0 / math.sqrt(D)
    p = {
        "router": (jax.random.normal(ks[0], (D, E)) * s).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, D, F)) * s).astype(dt),
        "w_up": (jax.random.normal(ks[2], (E, D, F)) * s).astype(dt),
        "w_down": (jax.random.normal(ks[3], (E, F, D)) / math.sqrt(F)).astype(dt),
    }
    if m.n_shared_experts:
        p["shared"] = init_mlp(ks[4], cfg, d_ff=F * m.n_shared_experts)
    return p


def moe_forward(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                group_size: int = 256) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x: (B,S,D) -> (out, aux_losses).  Grouped capacity-based dispatch:
    tokens are viewed as (G, Sg); each group independently routes to E
    experts with capacity C = Sg*k/E*cf.  Lowers to all-to-all when the
    expert dim is sharded over the 'model' mesh axis."""
    m: MoEConfig = cfg.moe
    B, S, D = x.shape
    T = B * S
    Sg = min(group_size, T)
    while T % Sg:
        Sg //= 2
    G = T // Sg
    xg = x.reshape(G, Sg, D)
    logits = (xg.astype(jnp.float32) @ p["router"])            # (G,Sg,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, m.top_k)        # (G,Sg,k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)                # renormalize

    C = max(4, int(Sg * m.top_k / m.n_experts * m.capacity_factor))
    C = min(C, Sg)
    # position of each (token, slot) within its expert queue
    onehot = jax.nn.one_hot(gate_idx, m.n_experts, dtype=jnp.float32)  # (G,Sg,k,E)
    flat = onehot.reshape(G, Sg * m.top_k, m.n_experts)
    pos = jnp.cumsum(flat, axis=1) * flat - 1.0                # (G,Sg*k,E)
    pos = pos.reshape(G, Sg, m.top_k, m.n_experts)
    keep = (pos >= 0) & (pos < C)
    pos_e = jnp.where(keep, pos, 0).astype(jnp.int32).max(-1)  # (G,Sg,k)
    keep_k = keep.any(-1)                                      # (G,Sg,k)
    # build (G,Sg,E,C) per top-k slot to avoid a 5-D (G,Sg,k,E,C) buffer
    dispatch = jnp.zeros((G, Sg, m.n_experts, C), x.dtype)
    combine = jnp.zeros((G, Sg, m.n_experts, C), x.dtype)
    for j in range(m.top_k):
        oh_c = jax.nn.one_hot(pos_e[:, :, j], C, dtype=x.dtype) \
            * keep_k[:, :, j, None].astype(x.dtype)            # (G,Sg,C)
        oh_e = onehot[:, :, j].astype(x.dtype)                 # (G,Sg,E)
        d_j = oh_e[..., None] * oh_c[:, :, None, :]
        dispatch = dispatch + d_j
        combine = combine + gate_vals[:, :, j, None, None].astype(x.dtype) * d_j

    ex_in = jnp.einsum("gsec,gsd->egcd", dispatch, xg)         # (E,G,C,D)
    h_g = jnp.einsum("egcd,edf->egcf", ex_in, p["w_gate"].astype(x.dtype))
    h_u = jnp.einsum("egcd,edf->egcf", ex_in, p["w_up"].astype(x.dtype))
    h = jax.nn.silu(h_g) * h_u
    ex_out = jnp.einsum("egcf,efd->egcd", h, p["w_down"].astype(x.dtype))
    out = jnp.einsum("gsec,egcd->gsd", combine, ex_out).reshape(B, S, D)

    # aux losses (Switch-style load balance + router z-loss)
    frac_tokens = jnp.mean(onehot.sum(2), axis=(0, 1))          # (E,)
    frac_probs = jnp.mean(probs, axis=(0, 1))                   # (E,)
    lb = m.n_experts * jnp.sum(frac_tokens * frac_probs)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    aux = {"moe_load_balance": m.router_aux_coef * lb,
           "moe_z_loss": m.router_z_coef * z}
    if "shared" in p:
        out = out + mlp_forward(p["shared"], x, cfg)
    return out, aux
