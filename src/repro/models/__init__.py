from repro.models.model import (  # noqa: F401
    decode_step, forward, init_caches, init_params, lm_loss, param_count,
    active_param_count,
)
