"""Unified model API: init, full-sequence forward (train / prefill),
single-token decode against caches, and the LM loss.

A "batch" is a dict with keys depending on the family:
  tokens        (B,S) int32                        — always
  positions     (B,S) int32                        — optional (default arange)
  mrope_pos     (3,B,S) int32                      — vlm (M-RoPE)
  vision_embeds (B,P,D)                            — vlm patch-embedding stub
  frames        (B,T,D)                            — audio frontend stub
For decode steps the dict carries a single token column (B,1).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_params(key, cfg: ModelConfig) -> Params:
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, cfg.n_layers + 4)
    p: Params = {
        "embed": (jax.random.normal(ks[0], (cfg.padded_vocab(), cfg.d_model))
                  * 0.02).astype(dt),
        "final_norm": L.init_norm(ks[1], cfg, cfg.d_model),
        "blocks": [],
    }
    for i in range(cfg.n_layers):
        blk = T.init_block(ks[2 + i], cfg, i)
        if cfg.encoder is not None and cfg.block_kind(i) == "attn":
            blk = T.init_cross_attention(jax.random.fold_in(ks[2 + i], 7), cfg, blk)
        p["blocks"].append(blk)
    if not cfg.tie_embeddings:
        p["lm_head"] = (jax.random.normal(ks[-2],
                                          (cfg.d_model, cfg.padded_vocab()))
                        / (cfg.d_model ** 0.5)).astype(dt)
    if cfg.encoder is not None:
        p["encoder"] = T.init_encoder(ks[-1], cfg)
    return p


def init_caches(cfg: ModelConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16) -> Params:
    return {
        "layers": [T.init_block_cache(cfg, i, batch, max_len, dtype)
                   for i in range(cfg.n_layers)],
        "index": jnp.zeros((), jnp.int32),
    }


def param_count(params: Params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))


def active_param_count(cfg: ModelConfig, params: Params) -> int:
    """MoE-aware: only top_k + shared experts count per token."""
    total = param_count(params)
    if cfg.moe is None:
        return total
    m = cfg.moe
    inactive = 0
    for i, blk in enumerate(params["blocks"]):
        if "moe" in blk:
            per_expert = sum(blk["moe"][k].size // m.n_experts
                             for k in ("w_gate", "w_up", "w_down"))
            inactive += per_expert * (m.n_experts - m.top_k)
    return total - inactive


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _embed_inputs(params: Params, batch: Dict[str, jnp.ndarray],
                  cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray, Optional[jnp.ndarray]]:
    """Returns (x, positions, mrope_pos)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    cdt = jnp.dtype(cfg.compute_dtype)
    x = params["embed"][tokens].astype(cdt) * cfg.emb_scale
    mrope = batch.get("mrope_pos")
    if cfg.vision is not None and "vision_embeds" in batch:
        vis = batch["vision_embeds"].astype(cdt)
        x = jnp.concatenate([vis, x], axis=1)           # vision prefix
        S = x.shape[1]
    if "positions" in batch:
        pos = batch["positions"]
    else:
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if cfg.pos_type == "mrope" and mrope is None:
        mrope = jnp.broadcast_to(pos[None], (3, B, S))  # text-only: t=h=w
    if cfg.pos_type == "learned":
        # whisper decoder learned positions approximated by sinusoidal here
        x = x + L.sinusoidal_embedding(S, cfg.d_model).astype(cdt)[None]
    x = _constrain_act(x, cfg)
    return x, pos, mrope


def _constrain_act(x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Pin the residual stream's batch dim to a mesh axis (hillclimb A3:
    GSPMD does not propagate batch sharding through the replica-vmap + layer
    scan on its own)."""
    if not cfg.act_dp_axis and not cfg.act_seq_axis:
        return x
    from jax.sharding import PartitionSpec as P
    spec = P(cfg.act_dp_axis or None, cfg.act_seq_axis or None,
             *(None,) * (x.ndim - 2))
    return jax.lax.with_sharding_constraint(x, spec)


def forward(params: Params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Full-sequence forward.  Returns (logits (B,S,V), aux losses).

    When ``cfg.scan_grouping()`` applies, the repeating layer groups run
    under ``jax.lax.scan`` (compile time ~O(1) in depth — essential for the
    56-72 layer configs); otherwise a python loop."""
    x, pos, mrope = _embed_inputs(params, batch, cfg)
    cross_kv_cache = _encode_cross(params, batch, cfg)
    aux_total: Dict[str, jnp.ndarray] = {}

    def run_block(blk, x, i):
        ckv = _layer_cross_kv(blk, cross_kv_cache, cfg)
        return T.block_forward(blk, x, cfg, i, positions=pos,
                               cross_kv=ckv, mrope_pos=mrope)

    def acc_aux(aux):
        for k, v in aux.items():
            aux_total[k] = aux_total.get(k, 0.0) + v

    policy = (jax.checkpoint_policies.dots_saveable
              if cfg.remat_policy == "dots"
              else jax.checkpoint_policies.nothing_saveable)

    grouping = cfg.scan_grouping()
    prefix = cfg.n_layers if grouping is None else grouping[0]
    for i in range(prefix):
        if cfg.remat:
            y, aux, _ = jax.checkpoint(
                lambda x_, i_=i: run_block(params["blocks"][i_], x_, i_),
                policy=policy)(x)
        else:
            y, aux, _ = run_block(params["blocks"][i], x, i)
        x = y
        acc_aux(aux)

    if grouping is not None:
        _, P, G = grouping
        body = params["blocks"][prefix:]
        # stack the g-th repetition of slot j: (G, ...) leading dim per leaf
        stacked = tuple(
            jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                   *[body[g * P + j] for g in range(G)])
            for j in range(P))

        def group_fn(x, grp):
            aux_g: Dict[str, jnp.ndarray] = {}
            for j in range(P):
                x, aux, _ = run_block(grp[j], x, prefix + j)
                x = _constrain_act(x, cfg)
                for k, v in aux.items():
                    aux_g[k] = aux_g.get(k, 0.0) + v
            return x, aux_g

        if cfg.remat:
            group_fn = jax.checkpoint(group_fn, policy=policy)
        x, aux_stk = jax.lax.scan(group_fn, x, stacked)
        acc_aux({k: jnp.sum(v) for k, v in aux_stk.items()})

    x = L.norm_forward(params["final_norm"], x, cfg)
    logits = _lm_head(params, x, cfg)
    return logits, aux_total


def _lm_head(params, x, cfg):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head.astype(x.dtype)) * cfg.logit_scale
    Vp = cfg.padded_vocab()
    if Vp != cfg.vocab_size:
        # mask padded columns (elementwise on the sharded vocab dim — no
        # re-gather); loss/argmax then never select them
        col = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                       logits.ndim - 1)
        logits = jnp.where(col < cfg.vocab_size, logits, -1e30)
    return logits


def _encode_cross(params, batch, cfg) -> Optional[jnp.ndarray]:
    if cfg.encoder is None:
        return None
    frames = batch["frames"]
    return T.encoder_forward(params["encoder"], frames.astype(
        jnp.dtype(cfg.compute_dtype)), cfg)


def _layer_cross_kv(blk, enc_out, cfg):
    if enc_out is None or "cross" not in blk:
        return None
    B, Te, D = enc_out.shape
    k = L.dense(blk["cross"]["wk"], enc_out).reshape(
        B, Te, cfg.n_kv_heads, cfg.head_dim())
    v = L.dense(blk["cross"]["wv"], enc_out).reshape(
        B, Te, cfg.n_kv_heads, cfg.head_dim())
    return (k, v)


def decode_step(params: Params, batch: Dict[str, jnp.ndarray], caches: Params,
                cfg: ModelConfig) -> Tuple[jnp.ndarray, Params]:
    """One-token decode.  batch["tokens"]: (B,1).  Returns (logits (B,1,V),
    updated caches)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    assert S == 1
    idx = caches["index"]
    cdt = jnp.dtype(cfg.compute_dtype)
    x = params["embed"][tokens].astype(cdt) * cfg.emb_scale
    pos = batch.get("positions",
                    jnp.broadcast_to(idx[None, None], (B, 1)).astype(jnp.int32))
    mrope = batch.get("mrope_pos")
    if cfg.pos_type == "mrope" and mrope is None:
        mrope = jnp.broadcast_to(pos[None], (3, B, 1))
    if cfg.pos_type == "learned":
        D = cfg.d_model
        dim = jnp.arange(D // 2, dtype=jnp.float32)
        ang = idx.astype(jnp.float32) / jnp.power(10000.0, 2 * dim / D)
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])
        x = x + pe.astype(cdt)[None, None, :]
    enc_out = batch.get("encoder_out")
    new_layers = []
    for i in range(cfg.n_layers):
        blk = params["blocks"][i]
        ckv = _layer_cross_kv(blk, enc_out, cfg)
        x, _, nc = T.block_forward(blk, x, cfg, i, positions=pos,
                                   cache=caches["layers"][i], cache_index=idx,
                                   cross_kv=ckv, mrope_pos=mrope)
        new_layers.append(nc)
    x = L.norm_forward(params["final_norm"], x, cfg)
    logits = _lm_head(params, x, cfg)
    return logits, {"layers": new_layers, "index": idx + 1}


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def lm_loss(params: Params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Next-token cross-entropy (+ MoE aux)."""
    logits, aux = forward(params, batch, cfg)
    tokens = batch["tokens"]
    # vision prefix (if any) is not scored
    S = tokens.shape[1]
    logits = logits[:, -S:, :]
    tgt = tokens[:, 1:]
    lg = logits[:, :-1, :].astype(jnp.float32)
    # vocab-parallel-friendly cross entropy: logsumexp + one-hot contraction
    # reduce over the (possibly 'model'-sharded) vocab dim with scalar-sized
    # collectives instead of gathering full logits (take_along_axis would).
    lse = jax.nn.logsumexp(lg, axis=-1)
    onehot = jax.nn.one_hot(tgt, lg.shape[-1], dtype=lg.dtype)
    picked = jnp.einsum("bsv,bsv->bs", lg, onehot)
    nll = lse - picked
    mask = batch.get("loss_mask", jnp.ones_like(tgt, jnp.float32))
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    total = loss + sum(aux.values()) if aux else loss
    aux = dict(aux, ce_loss=loss)
    return total, aux
