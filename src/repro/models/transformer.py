"""Block composition: attention / mamba / mLSTM / sLSTM mixers + MLP/MoE
feed-forward sublayers, decoder-only LMs, and enc-dec (whisper) towers."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import xlstm as X

Params = Dict[str, Any]


def _has_ffn(cfg: ModelConfig, layer_idx: int) -> bool:
    kind = cfg.block_kind(layer_idx)
    if kind in ("mlstm", "slstm"):
        return False                      # xLSTM blocks are self-contained
    return cfg.d_ff > 0 or cfg.moe is not None


def init_block(key, cfg: ModelConfig, layer_idx: int) -> Params:
    ks = jax.random.split(key, 4)
    kind = cfg.block_kind(layer_idx)
    p: Params = {"norm1": L.init_norm(ks[0], cfg, cfg.d_model)}
    if kind == "attn":
        p["attn"] = L.init_attention(ks[1], cfg)
    elif kind == "mamba":
        p["mamba"] = M.init_mamba(ks[1], cfg)
    elif kind == "mlstm":
        p["mlstm"] = X.init_mlstm(ks[1], cfg)
    elif kind == "slstm":
        p["slstm"] = X.init_slstm(ks[1], cfg)
    else:
        raise ValueError(kind)
    if _has_ffn(cfg, layer_idx):
        p["norm2"] = L.init_norm(ks[2], cfg, cfg.d_model)
        if cfg.layer_uses_moe(layer_idx):
            p["moe"] = L.init_moe(ks[3], cfg)
        else:
            m = cfg.moe
            d_ff = (m.d_ff_dense or cfg.d_ff) if (m and layer_idx < m.first_k_dense) \
                else cfg.d_ff
            p["mlp"] = L.init_mlp(ks[3], cfg, d_ff=d_ff)
    return p


def init_block_cache(cfg: ModelConfig, layer_idx: int, batch: int,
                     max_len: int, dtype=jnp.bfloat16) -> Optional[Params]:
    kind = cfg.block_kind(layer_idx)
    if kind == "attn":
        return L.init_kv_cache(cfg, batch, max_len, dtype)
    if kind == "mamba":
        return M.init_mamba_state(cfg, batch)
    if kind == "mlstm":
        return X.init_mlstm_state(cfg, batch)
    if kind == "slstm":
        return X.init_slstm_state(cfg, batch)
    raise ValueError(kind)


def block_forward(p: Params, x: jnp.ndarray, cfg: ModelConfig, layer_idx: int, *,
                  positions: jnp.ndarray,
                  cache: Optional[Params] = None,
                  cache_index: Optional[jnp.ndarray] = None,
                  cross_kv=None, mrope_pos=None,
                  ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray], Optional[Params]]:
    kind = cfg.block_kind(layer_idx)
    h = L.norm_forward(p["norm1"], x, cfg)
    new_cache = None
    if kind == "attn":
        h, new_cache = L.attention_forward(
            p["attn"], h, cfg, positions=positions, cache=cache,
            cache_index=cache_index, mrope_pos=mrope_pos)
    elif kind == "mamba":
        h, new_cache = M.mamba_forward(p["mamba"], h, cfg, state=cache)
    elif kind == "mlstm":
        h, new_cache = X.mlstm_forward(p["mlstm"], h, cfg, state=cache)
    elif kind == "slstm":
        h, new_cache = X.slstm_forward(p["slstm"], h, cfg, state=cache)
    x = x + h * cfg.residual_scale
    aux: Dict[str, jnp.ndarray] = {}
    if "norm2" in p:
        h = L.norm_forward(p["norm2"], x, cfg)
        if "moe" in p:
            h, aux = L.moe_forward(p["moe"], h, cfg)
        else:
            h = L.mlp_forward(p["mlp"], h, cfg)
        x = x + h * cfg.residual_scale
    if cross_kv is not None and "cross" in p:
        # whisper-style: cross-attention sublayer between self-attn and mlp;
        # applied after for simplicity of the residual stream (documented).
        h = L.norm_forward(p["cross_norm"], x, cfg)
        h, _ = L.attention_forward(p["cross"], h, cfg, positions=positions,
                                   cross_kv=cross_kv)
        x = x + h * cfg.residual_scale
    return x, aux, new_cache


def init_cross_attention(key, cfg: ModelConfig, p: Params) -> Params:
    ks = jax.random.split(key, 2)
    p["cross"] = L.init_attention(ks[0], cfg)
    p["cross_norm"] = L.init_norm(ks[1], cfg, cfg.d_model)
    return p


# ---------------------------------------------------------------------------
# Encoder tower (whisper) — bidirectional, sinusoidal positions.
# ---------------------------------------------------------------------------


def init_encoder(key, cfg: ModelConfig) -> Params:
    e = cfg.encoder
    ks = jax.random.split(key, e.n_layers + 1)
    blocks = []
    import dataclasses
    ecfg = dataclasses.replace(cfg, n_heads=e.n_heads, n_kv_heads=e.n_heads,
                               layer_pattern=None, moe=None, d_head=0)
    for i in range(e.n_layers):
        blocks.append({
            "norm1": L.init_norm(ks[i], ecfg, cfg.d_model),
            "attn": L.init_attention(jax.random.fold_in(ks[i], 1), ecfg),
            "norm2": L.init_norm(jax.random.fold_in(ks[i], 2), ecfg, cfg.d_model),
            "mlp": L.init_mlp(jax.random.fold_in(ks[i], 3), ecfg),
        })
    return {"blocks": blocks, "final_norm": L.init_norm(ks[-1], ecfg, cfg.d_model)}


def encoder_forward(p: Params, frames: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """frames: (B,T,D) post-frontend embeddings (stub)."""
    import dataclasses
    e = cfg.encoder
    ecfg = dataclasses.replace(cfg, n_heads=e.n_heads, n_kv_heads=e.n_heads,
                               layer_pattern=None, moe=None, d_head=0,
                               pos_type="none", sliding_window=0)
    B, T, D = frames.shape
    x = frames + L.sinusoidal_embedding(T, D).astype(frames.dtype)[None]

    def block(x, blk):
        h = L.norm_forward(blk["norm1"], x, ecfg)
        # bidirectional: mask = everything visible
        q = L.dense(blk["attn"]["wq"], h).reshape(B, T, e.n_heads, D // e.n_heads)
        k = L.dense(blk["attn"]["wk"], h).reshape(B, T, e.n_heads, D // e.n_heads)
        v = L.dense(blk["attn"]["wv"], h).reshape(B, T, e.n_heads, D // e.n_heads)
        mask = jnp.ones((B, T, T), bool)
        o = L._sdpa(q, k, v, mask)
        x = x + L.dense(blk["attn"]["wo"], o.reshape(B, T, D))
        h = L.norm_forward(blk["norm2"], x, ecfg)
        x = x + L.mlp_forward(blk["mlp"], h, ecfg)
        return x

    if cfg.scan_layers and len(p["blocks"]) >= 2:
        stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                         *p["blocks"])
        body = (lambda x, blk: (block(x, blk), None))
        if cfg.remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = jax.lax.scan(body, x, stacked)
    else:
        for blk in p["blocks"]:
            x = block(x, blk)
    return L.norm_forward(p["final_norm"], x, ecfg)
