"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix-memory, parallelizable)
and sLSTM (scalar-memory, strictly recurrent).

The mLSTM is implemented in *chunkwise-parallel* form: the sequence is cut
into chunks; a sequential `lax.scan` carries the stabilized matrix state
across chunks while each chunk computes its quadratic part locally.  This is
the TPU-native formulation (MXU-friendly intra-chunk matmuls, O(S·L) memory
instead of O(S²)) and is what makes the 500k-token decode shape feasible.

Stabilization follows the paper: with a_t = Σ_{r≤t} log f_r and
b_s = log i_s − a_s, the output weights are exp(b_s − μ_t) with
μ_t = max(m_state, cummax_{s≤t} b_s); the carried state is C·e^{−m}.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = Dict[str, Any]

CHUNK = 256
CONV_K = 4


def _heads(cfg: ModelConfig) -> Tuple[int, int]:
    H = cfg.n_heads
    return H, cfg.d_model // H


# ---------------------------------------------------------------------------
# mLSTM block (pre-up-projection, factor 2)
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg: ModelConfig) -> Params:
    dt = jnp.dtype(cfg.param_dtype)
    D = cfg.d_model
    Di = 2 * D
    H, dh = cfg.n_heads, (2 * D) // cfg.n_heads
    ks = jax.random.split(key, 8)
    s = 1.0 / math.sqrt(D)
    si = 1.0 / math.sqrt(Di)
    return {
        "up": (jax.random.normal(ks[0], (D, 2 * Di)) * s).astype(dt),
        "conv_w": (jax.random.normal(ks[1], (CONV_K, Di)) / math.sqrt(CONV_K)).astype(dt),
        "conv_b": jnp.zeros((Di,), dt),
        "wq": (jax.random.normal(ks[2], (Di, Di)) * si).astype(dt),
        "wk": (jax.random.normal(ks[3], (Di, Di)) * si).astype(dt),
        "wv": (jax.random.normal(ks[4], (Di, Di)) * si).astype(dt),
        "w_if": (jax.random.normal(ks[5], (Di, 2 * H)) * si).astype(dt),
        "b_i": jnp.zeros((H,), dt),
        "b_f": jnp.full((H,), 3.0, dt),      # forget gate bias -> remember
        "ogate_norm": jnp.ones((Di,), dt),   # per-head groupnorm scale
        "down": (jax.random.normal(ks[6], (Di, D)) * si).astype(dt),
    }


def init_mlstm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Params:
    H = cfg.n_heads
    dh = (2 * cfg.d_model) // H
    Di = 2 * cfg.d_model
    return {
        "C": jnp.zeros((batch, H, dh, dh), dtype),
        "n": jnp.zeros((batch, H, dh), dtype),
        "m": jnp.full((batch, H), -1e30, dtype),
        "conv": jnp.zeros((batch, CONV_K - 1, Di), dtype),
    }


def _headify(x, H):
    B, S, Di = x.shape
    return x.reshape(B, S, H, Di // H)


def _group_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5):
    """Per-head normalization of (B,S,H,dh)."""
    xf = x.astype(jnp.float32)
    mean = xf.mean(-1, keepdims=True)
    var = ((xf - mean) ** 2).mean(-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    B, S, H, dh = x.shape
    return (y.reshape(B, S, H * dh) * scale.astype(jnp.float32)).astype(x.dtype)


def mlstm_forward(p: Params, x: jnp.ndarray, cfg: ModelConfig, *,
                  state: Optional[Params] = None,
                  ) -> Tuple[jnp.ndarray, Optional[Params]]:
    B, S, D = x.shape
    H = cfg.n_heads
    Di = 2 * D
    dh = Di // H
    up = x @ p["up"].astype(x.dtype)
    xi, z = jnp.split(up, 2, axis=-1)                        # (B,S,Di) each

    # causal depthwise conv on the qk path
    if state is None:
        pad = jnp.zeros((B, CONV_K - 1, Di), xi.dtype)
        xp = jnp.concatenate([pad, xi], axis=1)
        new_conv = None
    else:
        xp = jnp.concatenate([state["conv"].astype(xi.dtype), xi], axis=1)
        new_conv = xp[:, 1:, :]
    conv = sum(xp[:, i:i + S, :] * p["conv_w"][i].astype(xi.dtype)
               for i in range(CONV_K)) + p["conv_b"].astype(xi.dtype)
    cx = jax.nn.silu(conv)

    q = _headify(cx @ p["wq"].astype(x.dtype), H) / math.sqrt(dh)
    k = _headify(cx @ p["wk"].astype(x.dtype), H)
    v = _headify(xi @ p["wv"].astype(x.dtype), H)
    gates = (cx @ p["w_if"].astype(x.dtype)).astype(jnp.float32)
    log_i = gates[..., :H] + p["b_i"].astype(jnp.float32)     # (B,S,H) exp input gate
    log_f = jax.nn.log_sigmoid(gates[..., H:] + p["b_f"].astype(jnp.float32))

    if state is not None:
        h, new_state = _mlstm_step(q[:, 0], k[:, 0], v[:, 0],
                                   log_i[:, 0], log_f[:, 0], state)
        h = h[:, None]                                        # (B,1,H,dh)
        new_state = {**new_state, "conv": new_conv.astype(state["conv"].dtype)}
    else:
        h = _mlstm_chunkwise(q, k, v, log_i, log_f)
        new_state = None

    h = _group_norm(h, p["ogate_norm"]) * jax.nn.silu(z)
    out = h @ p["down"].astype(x.dtype)
    return out, new_state


def _mlstm_step(q, k, v, log_i, log_f, state):
    """Single decode step.  q,k,v: (B,H,dh); log_i/f: (B,H)."""
    C, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(log_f + m, log_i)
    fs = jnp.exp(log_f + m - m_new)[..., None]
    is_ = jnp.exp(log_i - m_new)[..., None]
    C_new = fs[..., None] * C + is_[..., None] * (k[..., :, None] * v[..., None, :])
    n_new = fs * n + is_ * k
    num = jnp.einsum("bhd,bhde->bhe", q.astype(jnp.float32), C_new)
    den = jnp.einsum("bhd,bhd->bh", q.astype(jnp.float32), n_new)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    return h.astype(q.dtype), {"C": C_new, "n": n_new, "m": m_new}


def _mlstm_chunkwise(q, k, v, log_i, log_f):
    """q,k,v: (B,S,H,dh) ; log_i, log_f: (B,S,H).  Returns h (B,S,H,dh)."""
    B, S, H, dh = q.shape
    L = min(CHUNK, S)
    while S % L:
        L //= 2
    NC = S // L

    def rs(x):  # (B,S,...) -> (NC,B,L,...)
        return x.reshape(B, NC, L, *x.shape[2:]).swapaxes(0, 1)

    qc, kc, vc = rs(q.astype(jnp.float32)), rs(k.astype(jnp.float32)), rs(v.astype(jnp.float32))
    lic, lfc = rs(log_i), rs(log_f)

    def chunk(carry, xs):
        C, n, m = carry                                     # (B,H,dh,dh),(B,H,dh),(B,H)
        qj, kj, vj, li, lf = xs                             # (B,L,...)
        a = jnp.cumsum(lf, axis=1)                          # (B,L,H) local cumsum log f
        b = li - a                                          # (B,L,H)
        bmax = jax.lax.cummax(b, axis=1)
        mu = jnp.maximum(m[:, None], bmax)                  # (B,L,H)
        # intra-chunk quadratic part
        wloc = jnp.exp(b[:, None, :, :] - mu[:, :, None, :])      # (B,Lq,Ls,H)
        causal = jnp.tril(jnp.ones((L, L), bool))
        wloc = jnp.where(causal[None, :, :, None], wloc, 0.0)
        scores = jnp.einsum("bqhd,bshd->bqsh", qj, kj) * wloc
        num = jnp.einsum("bqsh,bshd->bqhd", scores, vj)
        den = scores.sum(axis=2)                                   # (B,L,H)
        # inter-chunk contribution from carried state
        wstate = jnp.exp(m[:, None] - mu)                          # (B,L,H)
        num = num + wstate[..., None] * jnp.einsum("blhd,bhde->blhe", qj, C)
        den = den + wstate * jnp.einsum("blhd,bhd->blh", qj, n)
        # true max exponent at step l is ā_l + mu_l (ā cancels in the
        # weights but NOT in the |den| >= exp(-m) stabilizer clamp)
        hj = num / jnp.maximum(jnp.abs(den), jnp.exp(-(a + mu)))[..., None]
        # advance state to end of chunk
        A = a[:, -1]                                               # (B,H)
        m_end = jnp.maximum(m + A, A + bmax[:, -1])
        w_in = jnp.exp(A[:, None] + b - m_end[:, None])            # (B,L,H)
        C_new = jnp.exp(m + A - m_end)[..., None, None] * C + \
            jnp.einsum("blh,blhd,blhe->bhde", w_in, kj, vj)
        n_new = jnp.exp(m + A - m_end)[..., None] * n + \
            jnp.einsum("blh,blhd->bhd", w_in, kj)
        return (C_new, n_new, m_end), hj

    C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, H, dh), jnp.float32)
    m0 = jnp.full((B, H), -1e30, jnp.float32)
    _, hs = jax.lax.scan(chunk, (C0, n0, m0), (qc, kc, vc, lic, lfc))
    h = hs.swapaxes(0, 1).reshape(B, S, H, dh)
    return h.astype(q.dtype)


# ---------------------------------------------------------------------------
# sLSTM block (post-up-projection) — strictly recurrent
# ---------------------------------------------------------------------------


def init_slstm(key, cfg: ModelConfig) -> Params:
    dt = jnp.dtype(cfg.param_dtype)
    D, H = cfg.d_model, cfg.n_heads
    dh = D // H
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(D)
    ff = max(1, int(D * 4 / 3 / 64) * 64) if cfg.d_ff == 0 else cfg.d_ff
    return {
        "wx": (jax.random.normal(ks[0], (D, 4 * D)) * s).astype(dt),     # i,f,z,o
        "r": (jax.random.normal(ks[1], (H, dh, 4 * dh)) / math.sqrt(dh)).astype(dt),
        "b": jnp.concatenate([jnp.zeros((D,)), jnp.full((D,), 3.0),
                              jnp.zeros((2 * D,))]).astype(dt),
        "gn": jnp.ones((D,), dt),
        "ff_gate": (jax.random.normal(ks[2], (D, ff)) * s).astype(dt),
        "ff_up": (jax.random.normal(ks[3], (D, ff)) * s).astype(dt),
        "ff_down": (jax.random.normal(ks[4], (ff, D)) / math.sqrt(ff)).astype(dt),
    }


def init_slstm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Params:
    D = cfg.d_model
    return {
        "c": jnp.zeros((batch, D), dtype),
        "n": jnp.zeros((batch, D), dtype),
        "h": jnp.zeros((batch, D), dtype),
        "m": jnp.full((batch, D), -1e30, dtype),
    }


def _slstm_cell(p, xt, st, cfg: ModelConfig):
    """xt: (B,4D) pre-computed input contribution; st: state dict."""
    H = cfg.n_heads
    D = cfg.d_model
    dh = D // H
    B = xt.shape[0]
    hprev = st["h"].reshape(B, H, dh)
    rec = jnp.einsum("bhd,hde->bhe", hprev.astype(jnp.float32),
                     p["r"].astype(jnp.float32)).reshape(B, 4 * D)
    pre = xt.astype(jnp.float32) + rec + p["b"].astype(jnp.float32)
    li_, lf_, z_, o_ = jnp.split(pre, 4, axis=-1)
    log_i = li_                                    # exponential input gate
    log_f = jax.nn.log_sigmoid(lf_)
    z = jnp.tanh(z_)
    o = jax.nn.sigmoid(o_)
    m_new = jnp.maximum(log_f + st["m"], log_i)
    fs = jnp.exp(log_f + st["m"] - m_new)
    is_ = jnp.exp(log_i - m_new)
    c_new = fs * st["c"] + is_ * z
    n_new = fs * st["n"] + is_
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}


def slstm_forward(p: Params, x: jnp.ndarray, cfg: ModelConfig, *,
                  state: Optional[Params] = None,
                  ) -> Tuple[jnp.ndarray, Optional[Params]]:
    B, S, D = x.shape
    xg = x @ p["wx"].astype(x.dtype)                          # (B,S,4D)

    if state is not None:
        st = {k: v.astype(jnp.float32) for k, v in state.items()}
        st = _slstm_cell(p, xg[:, 0], st, cfg)
        h = st["h"][:, None]
        new_state = {k: v.astype(state[k].dtype) for k, v in st.items()}
    else:
        st0 = {k: v.astype(jnp.float32)
               for k, v in init_slstm_state(cfg, B).items()}

        def step(st, xt):
            st = _slstm_cell(p, xt, st, cfg)
            return st, st["h"]

        _, hs = jax.lax.scan(step, st0, xg.swapaxes(0, 1))
        h = hs.swapaxes(0, 1)                                 # (B,S,D)
        new_state = None

    h = _group_norm(h.reshape(B, -1, cfg.n_heads, D // cfg.n_heads),
                    p["gn"]).astype(x.dtype)
    # gated feed-forward (post-up-projection block)
    y = (jax.nn.silu(h @ p["ff_gate"].astype(x.dtype)) *
         (h @ p["ff_up"].astype(x.dtype))) @ p["ff_down"].astype(x.dtype)
    return y, new_state
