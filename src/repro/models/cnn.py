"""Small VGG-style CNN for the paper-faithful CIFAR-10-scale experiments
(the paper trains GoogLeNet/VGG16 on CIFAR-10; we reproduce the *algorithmic*
claims — variance curves, adaptive period trajectory, convergence ordering —
with a compact CNN on synthetic 32x32 data so they run on this container)."""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def init_cnn(key, n_classes: int = 10, widths=(32, 64, 128), dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, len(widths) + 2)
    p: Params = {"convs": []}
    c_in = 3
    for i, w in enumerate(widths):
        p["convs"].append({
            "w": (jax.random.normal(ks[i], (3, 3, c_in, w))
                  * math.sqrt(2.0 / (9 * c_in))).astype(dtype),
            "b": jnp.zeros((w,), dtype),
        })
        c_in = w
    feat = widths[-1] * (32 // (2 ** len(widths))) ** 2
    p["fc1"] = {"w": (jax.random.normal(ks[-2], (feat, 256)) * math.sqrt(2.0 / feat)).astype(dtype),
                "b": jnp.zeros((256,), dtype)}
    p["fc2"] = {"w": (jax.random.normal(ks[-1], (256, n_classes)) / math.sqrt(256)).astype(dtype),
                "b": jnp.zeros((n_classes,), dtype)}
    return p


def cnn_forward(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    """x: (B,32,32,3) -> logits (B,n_classes)."""
    for c in p["convs"]:
        x = jax.lax.conv_general_dilated(
            x, c["w"], window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC")) + c["b"]
        x = jax.nn.relu(x)
        x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                  (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ p["fc1"]["w"] + p["fc1"]["b"])
    return x @ p["fc2"]["w"] + p["fc2"]["b"]


def cnn_loss(p: Params, batch: Dict[str, jnp.ndarray]) -> Tuple[jnp.ndarray, Dict]:
    logits = cnn_forward(p, batch["images"])
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][:, None], axis=-1)[:, 0]
    loss = jnp.mean(nll)
    acc = jnp.mean((jnp.argmax(logits, -1) == batch["labels"]).astype(jnp.float32))
    return loss, {"ce_loss": loss, "accuracy": acc}
