"""Mamba (selective SSM) block for the Jamba hybrid architecture.

Train/prefill uses an associative scan over the sequence (TPU-friendly:
log-depth, no sequential loop); decode updates an explicit recurrent state.
Reference: Gu & Dao 2023; Jamba (arXiv:2403.19887) interleaves this block
with attention at a 1:7 ratio.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MambaConfig, ModelConfig

Params = Dict[str, Any]


def _dt_rank(cfg: ModelConfig) -> int:
    m = cfg.mamba
    return m.dt_rank or max(1, math.ceil(cfg.d_model / 16))


def d_inner(cfg: ModelConfig) -> int:
    return cfg.mamba.expand * cfg.d_model


def init_mamba(key, cfg: ModelConfig) -> Params:
    m: MambaConfig = cfg.mamba
    dt = jnp.dtype(cfg.param_dtype)
    D, Di, R, N = cfg.d_model, d_inner(cfg), _dt_rank(cfg), m.d_state
    ks = jax.random.split(key, 7)
    s = 1.0 / math.sqrt(D)
    # S4D-real initialization for A
    A = jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32), (Di, N))
    return {
        "in_proj": (jax.random.normal(ks[0], (D, 2 * Di)) * s).astype(dt),
        "conv_w": (jax.random.normal(ks[1], (m.d_conv, Di)) / math.sqrt(m.d_conv)).astype(dt),
        "conv_b": jnp.zeros((Di,), dt),
        "x_proj": (jax.random.normal(ks[2], (Di, R + 2 * N)) / math.sqrt(Di)).astype(dt),
        "dt_proj_w": (jax.random.normal(ks[3], (R, Di)) / math.sqrt(R)).astype(dt),
        "dt_proj_b": jnp.log(jnp.expm1(
            jnp.clip(jax.random.uniform(ks[4], (Di,)) * 0.099 + 0.001, 1e-4))).astype(dt),
        "A_log": jnp.log(A),                       # (Di,N) float32
        "D": jnp.ones((Di,), jnp.float32),
        "out_proj": (jax.random.normal(ks[5], (Di, D)) / math.sqrt(Di)).astype(dt),
    }


def init_mamba_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Params:
    Di, N, Kc = d_inner(cfg), cfg.mamba.d_state, cfg.mamba.d_conv
    return {
        "ssm": jnp.zeros((batch, Di, N), dtype),
        "conv": jnp.zeros((batch, Kc - 1, Di), dtype),
    }


def _ssm_params(p: Params, x: jnp.ndarray, cfg: ModelConfig):
    """x: (B,S,Di) -> (dt, B_mat, C_mat) selective parameters."""
    R, N = _dt_rank(cfg), cfg.mamba.d_state
    proj = x @ p["x_proj"].astype(x.dtype)                    # (B,S,R+2N)
    dt_r, Bm, Cm = jnp.split(proj, [R, R + N], axis=-1)
    dt = jax.nn.softplus(dt_r @ p["dt_proj_w"].astype(x.dtype)
                         + p["dt_proj_b"].astype(x.dtype))    # (B,S,Di)
    return dt.astype(jnp.float32), Bm.astype(jnp.float32), Cm.astype(jnp.float32)


def _scan_combine(a, b):
    """Associative combine for h_t = g_t * h_{t-1} + u_t (elementwise g)."""
    g1, u1 = a
    g2, u2 = b
    return g2 * g1, g2 * u1 + u2


def mamba_forward(p: Params, x: jnp.ndarray, cfg: ModelConfig, *,
                  state: Optional[Params] = None,
                  ) -> Tuple[jnp.ndarray, Optional[Params]]:
    """x: (B,S,D).  Full sequence if state is None, else single-step decode
    (S==1) updating the recurrent state."""
    m: MambaConfig = cfg.mamba
    B, S, D = x.shape
    Di, N, Kc = d_inner(cfg), m.d_state, m.d_conv
    xz = x @ p["in_proj"].astype(x.dtype)
    xs, z = jnp.split(xz, 2, axis=-1)                         # (B,S,Di) each

    if state is None:
        # depthwise causal conv via padding
        pad = jnp.zeros((B, Kc - 1, Di), xs.dtype)
        xp = jnp.concatenate([pad, xs], axis=1)               # (B,S+Kc-1,Di)
        conv = sum(xp[:, i:i + S, :] * p["conv_w"][i].astype(xs.dtype)
                   for i in range(Kc)) + p["conv_b"].astype(xs.dtype)
        new_conv_state = None
    else:
        xp = jnp.concatenate([state["conv"].astype(xs.dtype), xs], axis=1)  # (B,Kc,Di)
        conv = jnp.einsum("bkd,kd->bd", xp, p["conv_w"].astype(xs.dtype))[:, None, :] \
            + p["conv_b"].astype(xs.dtype)
        new_conv_state = xp[:, 1:, :]
    u = jax.nn.silu(conv)

    dt, Bm, Cm = _ssm_params(p, u, cfg)
    A = -jnp.exp(p["A_log"])                                  # (Di,N)
    uf = u.astype(jnp.float32)
    # discretize: g = exp(dt*A), inp = dt * B * x   (ZOH on B approximated Euler)
    g = jnp.exp(dt[..., None] * A)                            # (B,S,Di,N)
    inp = (dt * uf)[..., None] * Bm[:, :, None, :]            # (B,S,Di,N)

    if state is None:
        _, h = jax.lax.associative_scan(_scan_combine, (g, inp), axis=1)
        new_ssm = None
    else:
        h = g[:, 0] * state["ssm"].astype(jnp.float32) + inp[:, 0]
        new_ssm = h
        h = h[:, None]                                        # (B,1,Di,N)
    y = jnp.einsum("bsdn,bsn->bsd", h, Cm) + p["D"] * uf      # (B,S,Di)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(x.dtype)
    if state is None:
        return out, None
    return out, {"ssm": new_ssm.astype(state["ssm"].dtype),
                 "conv": new_conv_state.astype(state["conv"].dtype)}
