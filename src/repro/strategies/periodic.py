"""The paper's periodic-averaging strategies plus the FULLSGD baseline.

``PeriodicAveragingStrategy`` is the shared machinery: a collective-free
local step every iteration, and the replica-averaging sync program on the
schedule its ``PeriodController`` picks (constant / decreasing / adaptive —
Algorithms 1 and 2).  Both programs are ``CollectiveOp`` descriptors
(``step_op`` / ``sync_op``) lowered by the ``ExecutionBackend``
(``backend.lower``), so the same policy runs on one host device or sharded
over a mesh and is priced from the very descriptors it lowered.  The
controller hierarchy from ``core/controller.py`` survives as the
strategies' internal schedule state; the engine only ever sees ``actions``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Type

from repro.backends.ops import all_mean_op, full_step_op
from repro.configs.base import AveragingConfig
from repro.core.controller import (ADPSGDController, ConstantPeriodController,
                                   DecreasingPeriodController, PeriodController)
from repro.strategies.base import (STEP, SYNC, CommunicationStrategy,
                                   register_strategy)


class PeriodicAveragingStrategy(CommunicationStrategy):
    """Local SGD + controller-scheduled parameter averaging."""

    name = "periodic"
    controller_cls: Type[PeriodController] = ConstantPeriodController

    def __init__(self, cfg: AveragingConfig, total_steps: int,
                 controller: Optional[PeriodController] = None):
        super().__init__(cfg, total_steps)
        self.controller = self.controller_cls(cfg, total_steps)
        if controller is not None:
            self.set_controller(controller)

    def set_controller(self, controller: PeriodController) -> None:
        """Install a caller-built schedule (the seed loop's extension
        point): any PeriodController works for plain periodic averaging."""
        if not isinstance(controller, PeriodController):
            raise TypeError(f"expected a PeriodController, "
                            f"got {type(controller).__name__}")
        self.controller = controller

    def _build_programs(self, loss_fn, optimizer, backend):
        step = backend.lower(self.step_op(),
                             loss_fn=loss_fn, optimizer=optimizer)
        # always the full-precision all_mean op — subclasses whose
        # steady-state sync_op compresses (qsgd_periodic) still seed their
        # anchor through this program
        sync = backend.lower(all_mean_op(),
                             sync_momentum=self.cfg.sync_momentum)

        def step_prog(W, opt_state, batch, lr, key):
            W, opt_state, metrics = step(W, opt_state, batch, lr)
            return W, opt_state, dict(metrics)

        def sync_prog(W, opt_state, batch, lr, key):
            W, opt_state, s_k = sync(W, opt_state)
            return W, opt_state, {"s_k": s_k}

        return {STEP: step_prog, SYNC: sync_prog}

    def actions(self, k: int):
        if self.controller.sync_now(k):
            self._comm_events += 1
            return (STEP, SYNC)
        return (STEP,)

    def observe(self, k: int, lr: float, s_k: float) -> None:
        self.controller.observe(k, lr, s_k)

    def bind_clock(self, clock) -> None:
        # only time-driven controllers (AdaCommTimeController) declare the
        # hook; they validate that a clock is actually present
        if hasattr(self.controller, "bind_clock"):
            self.controller.bind_clock(clock)

    @property
    def period(self) -> int:
        return self.controller.period

    def state_dict(self) -> Dict[str, Any]:
        d = super().state_dict()
        d.update(self.controller.state_dict())
        return d

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        super().load_state_dict(state)
        self.controller.load_state_dict(state)


@register_strategy
class ConstantPeriodStrategy(PeriodicAveragingStrategy):
    """CPSGD (Algorithm 1): constant period p."""

    name = "cpsgd"
    controller_cls = ConstantPeriodController


@register_strategy
class AdaptivePeriodStrategy(PeriodicAveragingStrategy):
    """ADPSGD (Algorithm 2) — the paper's contribution."""

    name = "adpsgd"
    controller_cls = ADPSGDController


@register_strategy
class DecreasingPeriodStrategy(PeriodicAveragingStrategy):
    """Wang & Joshi's decreasing schedule (paper §V-B — shown harmful)."""

    name = "decreasing"
    controller_cls = DecreasingPeriodController


@register_strategy
class FullSGDStrategy(CommunicationStrategy):
    """FULLSGD: gradients all-reduced every iteration (p = 1).  Every step
    is a communication event; the replica-averaging sync program never runs
    because replicas stay bit-identical."""

    name = "fullsgd"

    def step_op(self):
        return full_step_op()

    def sync_op(self):
        # the communication event IS the fused step: one f32 ring
        # all-reduce of the gradients per iteration
        return full_step_op()

    def _build_programs(self, loss_fn, optimizer, backend):
        step = backend.lower(self.step_op(),
                             loss_fn=loss_fn, optimizer=optimizer)

        def step_prog(W, opt_state, batch, lr, key):
            W, opt_state, metrics = step(W, opt_state, batch, lr)
            return W, opt_state, dict(metrics)

        return {STEP: step_prog}

    def actions(self, k: int):
        self._comm_events += 1
        return (STEP,)

    def comm_events_for(self, total_steps: int, n_syncs: int) -> int:
        return total_steps
