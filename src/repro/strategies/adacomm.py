"""AdaComm: loss-adaptive communication period (Wang & Joshi, 1810.08313).

Where the source paper's ADPSGD pins the inter-sync parameter variance to
the learning rate (probe-driven), AdaComm drives the period from the
*training loss*: communicate rarely while the loss is high (local SGD makes
fast early progress without paying the all-reduce) and more often as it
falls (averaging tightens the error floor near convergence).  The schedule
is ``tau_j = ceil(tau_0 * sqrt(F_j / F_0))`` recomputed every
``cfg.adacomm_interval`` iterations — see ``AdaCommController``.

The strategy itself is the plain periodic machinery — it inherits the
``replica_step``/``all_mean`` CollectiveOp descriptors and their derived
pricing untouched; only the controller (and the ``observe_loss`` feedback
route) differ, which is exactly the separation the strategy/backend split
is for.  Because the clock is a first-class engine citizen, the time mode
adapts against the same honest bytes/latency the op descriptors price.
"""
from __future__ import annotations

from repro.configs.base import AveragingConfig
from repro.core.controller import AdaCommController, AdaCommTimeController
from repro.strategies.base import register_strategy
from repro.strategies.periodic import PeriodicAveragingStrategy


@register_strategy
class AdaCommStrategy(PeriodicAveragingStrategy):
    """Periodic averaging on AdaComm's error-runtime-adaptive schedule.

    ``cfg.adacomm_mode`` picks the block definition: ``'iterations'``
    (default — blocks of ``adacomm_interval`` iterations, bit-exact with
    the PR-2/3 behavior) or ``'time'`` (the paper's wall-clock form —
    blocks of ``adacomm_t0`` seconds on the engine's telemetry clock, with
    straggler rescaling; see ``AdaCommTimeController``)."""

    name = "adacomm"
    controller_cls = AdaCommController

    def __init__(self, cfg: AveragingConfig, total_steps: int, **kw):
        if cfg.adacomm_mode == "time":
            # instance attr shadows the class default before the base
            # __init__ instantiates the controller
            self.controller_cls = AdaCommTimeController
        elif cfg.adacomm_mode != "iterations":
            raise ValueError(
                f"unknown adacomm_mode '{cfg.adacomm_mode}'; "
                "use 'iterations' or 'time'")
        super().__init__(cfg, total_steps, **kw)

    def observe_loss(self, k: int, loss: float) -> None:
        self.controller.observe_loss(k, loss)
