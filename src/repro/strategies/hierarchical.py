"""Two-level hierarchical strategy for multi-pod meshes (beyond-paper).

Inner (in-pod, fast ICI) syncs average contiguous replica groups at a small
constant period; the outer (cross-pod, slow link) sync is the paper's
adaptive one.  When ``cfg.group_size`` is unset the group size comes from
the backend's topology (``backend.default_group_size()`` — replicas per pod
on a multi-pod mesh), so the hierarchy aligns with the pod boundary without
configuration.  This wires the previously-dead
``HierarchicalADPSGDController.inner_sync_now`` path end-to-end: the inner
counter is consulted every iteration, and an outer sync subsumes the inner
one (the global average already equalizes every group).  The inner average
is the ``inner_mean(group)`` CollectiveOp: a device-local reshape on the
vmap backend, an in-group ``pmean`` (fast ICI, never the cross-pod link) on
the mesh backend — and because the group rides the op descriptor, pricing
sees the group, never the world.

Comm accounting deliberately inherits the base hooks: the analytic model
(core/comm_model.py) prices the *slow cross-pod link*, which only outer
syncs traverse — inner group syncs ride the fast in-pod ICI whose cost the
model treats as free (that is the point of the hierarchy).  Inner sync
counts are still observable via ``TrainHistory.inner_sync_steps``.
"""
from __future__ import annotations

from typing import Any, Dict

import jax

from repro.backends.ops import inner_mean_op
from repro.core.controller import HierarchicalADPSGDController
from repro.strategies.base import INNER_SYNC, STEP, SYNC, register_strategy
from repro.strategies.periodic import PeriodicAveragingStrategy


@register_strategy
class HierarchicalADPSGDStrategy(PeriodicAveragingStrategy):
    """Inner constant-period group sync + outer adaptive sync."""

    name = "hier_adpsgd"
    controller_cls = HierarchicalADPSGDController

    def set_controller(self, controller) -> None:
        # actions() needs the two-level interface, not just sync_now
        if not isinstance(controller, HierarchicalADPSGDController):
            raise TypeError("hier_adpsgd needs a HierarchicalADPSGDController, "
                            f"got {type(controller).__name__}")
        self.controller = controller

    def _build_programs(self, loss_fn, optimizer, backend):
        programs = super()._build_programs(loss_fn, optimizer, backend)
        group_cfg = self.cfg.group_size
        built: Dict[int, Any] = {}

        def inner_prog(W, opt_state, batch, lr, key):
            R = jax.tree_util.tree_leaves(W)[0].shape[0]
            # group size: config wins; otherwise the backend's topology
            # (replicas per pod on a multi-pod mesh) so inner syncs align
            # with the pod boundary; else half the replicas form one group
            g = group_cfg or backend.default_group_size() or max(1, R // 2)
            while R % g:
                g -= 1
            if g not in built:
                # the inner op's group rides the descriptor, so the clock
                # prices the in-group ring (never the world) automatically
                built[g] = backend.lower(inner_mean_op(g))
            return built[g](W), opt_state, {"inner_sync": True}

        programs[INNER_SYNC] = inner_prog
        return programs

    def actions(self, k: int):
        if self.controller.sync_now(k):
            self._comm_events += 1
            # the global average subsumes the in-group one; don't record a
            # phantom inner sync
            self.controller.reset_inner()
            return (STEP, SYNC)
        if self.controller.inner_sync_now(k):
            return (STEP, INNER_SYNC)
        return (STEP,)
