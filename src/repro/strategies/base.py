"""The pluggable communication-strategy API.

The paper's thesis is that the communication *policy* — when and what the
replicas synchronize — is the variable worth optimizing.  A
``CommunicationStrategy`` therefore owns everything policy-specific:

* ``compile(loss_fn, optimizer, backend)`` — build the strategy's device
  programs by **emitting ``CollectiveOp`` descriptors**
  (``backends/ops.py``) and asking the ``ExecutionBackend`` to lower them
  (``backend.lower(op, ...)``): the op declares the collective kind, wire
  format, group and overlap hint; the backend owns device placement and
  how the exchange actually runs, so the same strategy compiles against
  one host device (vmap) or a sharded mesh.  Programs all share one
  signature ``(W, opt_state, batch, lr, key) -> (W, opt_state, info)`` so
  the engine can dispatch them without knowing what they are.
* ``actions(k)`` — the host-side per-iteration decision: which program
  names to dispatch at iteration k, in order.  This absorbs the old
  ``PeriodController`` hierarchy; decisions are plain python and stay off
  the device critical path (both programs are pre-compiled and dispatch is
  asynchronous — DESIGN.md §2).
* ``observe(k, lr, s_k)`` — feedback after a sync: the measured variance
  probe S_k drives adaptive policies (Algorithm 2 lines 14-19).
* ``sync_op()`` — the ``CollectiveOp`` describing one communication event.
  It is both what ``compile`` lowers for the sync program and the *sole*
  pricing source for the analytic accounting (``comm_bytes_per_sync`` /
  ``comm_stats`` derive bytes and latency structure from the descriptor —
  no parallel table to keep in sync).
* ``state_dict() / load_state_dict()`` — adaptive state (p, C2, counters)
  for checkpoint/resume; restoring must continue the same sync schedule.

Strategies register by name (``@register_strategy``); adding a new
communication scheme is one registered class, never an edit to the engine.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple, Type

from repro.backends.ops import (CollectiveOp, all_mean_op, replica_step_op)
from repro.configs.base import AveragingConfig
from repro.core.comm_model import CommStats, comm_time

Pytree = Any
# program: (W, opt_state, batch, lr, key) -> (W, opt_state, info)
#   info["loss"]        -> the engine records a training-loss sample
#   info["s_k"]         -> the program was a sync; engine feeds observe()
#   info["inner_sync"]  -> hierarchical inner (in-pod) sync marker
Program = Callable[..., Tuple[Pytree, Optional[Pytree], Dict[str, Any]]]

STEP = "step"
SYNC = "sync"
INNER_SYNC = "inner_sync"


class CommunicationStrategy:
    """Base class; concrete strategies override the hooks they need."""

    name = "base"

    def __init__(self, cfg: AveragingConfig, total_steps: int):
        self.cfg = cfg
        self.total_steps = total_steps
        self.programs: Dict[str, Program] = {}
        self.backend = None            # set by compile()
        self._comm_events = 0

    # ------------------------------------------------------------- programs
    def compile(self, loss_fn, optimizer, backend=None,
                avg_cfg: Optional[AveragingConfig] = None) -> None:
        """Build ``self.programs`` against ``backend`` (an
        ``ExecutionBackend`` instance, a registered backend name, or None
        for the default vmap backend).  Subclasses implement
        ``_build_programs(loss_fn, optimizer, backend)`` in terms of the
        backend's primitives.  ``avg_cfg``, if given, must equal the
        constructor config — the schedule state was built from that config
        in ``__init__``, so a different one here would silently desync
        programs from schedule."""
        if avg_cfg is not None and avg_cfg != self.cfg:
            raise ValueError(
                f"strategy '{self.name}' was constructed with a different "
                "AveragingConfig; rebuild it via make_strategy(avg_cfg, ...)")
        from repro.backends import resolve_backend
        self.backend = resolve_backend(backend)
        self.programs = self._build_programs(loss_fn, optimizer, self.backend)

    def _build_programs(self, loss_fn, optimizer, backend) -> Dict[str, Program]:
        raise NotImplementedError

    def dispatch(self, action: str, W, opt_state, batch, lr, key):
        return self.programs[action](W, opt_state, batch, lr, key)

    # ------------------------------------------------------------- decisions
    def actions(self, k: int) -> Tuple[str, ...]:
        """Program names to dispatch at iteration k, in order."""
        raise NotImplementedError

    def observe(self, k: int, lr: float, s_k: float) -> None:
        """Feedback after the sync program ran at iteration k."""

    def observe_loss(self, k: int, loss: float) -> None:
        """Per-step training loss feedback (the engine already reads the
        loss back for its history, so this costs nothing extra).  Drives
        loss-adaptive policies — AdaComm's error-runtime schedule."""

    def bind_clock(self, clock) -> None:
        """Hand the engine's telemetry clock (``runtime/clock.py``, may be
        None) to time-driven policies — the wall-clock AdaComm controller
        adapts per t0-second block of ``clock.now()``.  Base: ignore."""

    # ------------------------------------------------------------- telemetry
    @property
    def period(self) -> int:
        """Current averaging period (1 for every-step strategies)."""
        return 1

    @property
    def n_comm_events(self) -> int:
        """Communication events so far (syncs, or steps for every-step
        strategies) — drives ``TrainHistory.n_syncs``."""
        return self._comm_events

    # -------------------------------------------------------- op descriptors
    def step_op(self) -> CollectiveOp:
        """The per-iteration device program's descriptor (collective-free
        local step for periodic strategies; every-step baselines override
        with their fused-exchange step)."""
        return replica_step_op()

    def sync_op(self) -> CollectiveOp:
        """Descriptor of one communication event — what ``compile`` lowers
        for the sync program and what every accounting path prices.  Base:
        a full-precision ring all-reduce of the parameters."""
        return all_mean_op()

    # ------------------------------------------------------------ accounting
    # Derived from sync_op(): the analytic model prices the same descriptor
    # the backend lowered, so there is no second table to drift.  The
    # analytic hooks pass n_tensors=0 — side-channel norm bytes show up in
    # the *measured* wire-byte columns (Timeline), not the closed form.
    def comm_bytes_per_sync(self, n_params: int, n_nodes: int) -> float:
        """Bytes moved per node per communication event, priced from the
        strategy's ``sync_op`` wire format."""
        return self.sync_op().wire_bytes(n_params, n_nodes)

    def comm_events_for(self, total_steps: int, n_syncs: int) -> int:
        """How many communication events a run of ``total_steps`` with
        ``n_syncs`` recorded syncs performed."""
        return n_syncs

    def comm_stats(self, n_params: int, n_nodes: int, total_steps: int,
                   n_syncs: int, bandwidth: float) -> CommStats:
        per = self.comm_bytes_per_sync(n_params, n_nodes)
        ev = self.comm_events_for(total_steps, n_syncs)
        coll = self.sync_op().collective or "all_reduce"
        return CommStats(per, ev, comm_time(per, ev, n_nodes, bandwidth,
                                            collective=coll))

    # ------------------------------------------------------------ checkpoint
    def state_dict(self) -> Dict[str, Any]:
        return {"comm_events": self._comm_events}

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        self._comm_events = int(state.get("comm_events", 0))


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_STRATEGIES: Dict[str, Type[CommunicationStrategy]] = {}


def register_strategy(cls: Type[CommunicationStrategy]):
    """Class decorator: register under ``cls.name``."""
    if not cls.name or cls.name == "base":
        raise ValueError(f"{cls.__name__} needs a unique .name")
    _STRATEGIES[cls.name] = cls
    return cls


def get_strategy_cls(name: str) -> Type[CommunicationStrategy]:
    if name not in _STRATEGIES:
        raise KeyError(
            f"unknown strategy '{name}'; available: {available_strategies()}")
    return _STRATEGIES[name]


def make_strategy(cfg: AveragingConfig, total_steps: int,
                  name: Optional[str] = None, **kw) -> CommunicationStrategy:
    """Instantiate the strategy named ``name`` (default: ``cfg.method``)."""
    return get_strategy_cls(name or cfg.method)(cfg, total_steps, **kw)


def available_strategies() -> List[str]:
    return sorted(_STRATEGIES)


def comm_stats_for(name: str, cfg: AveragingConfig, n_params: int,
                   n_nodes: int, total_steps: int, n_syncs: int,
                   bandwidth: float) -> CommStats:
    """Analytic communication cost of a run, via the strategy's own
    accounting hooks (replaces string dispatch in ``method_comm``)."""
    s = make_strategy(cfg, total_steps, name=name)
    return s.comm_stats(n_params, n_nodes, total_steps, n_syncs, bandwidth)
