"""DaSGD: delayed averaging overlaps the sync with compute (2006.00441).

Synchronous periodic averaging stalls every replica while the all-reduce is
in flight.  DaSGD hides that latency: the average computed from the
parameters at step k is *applied* at step k + d (``cfg.dasgd_delay``), and
replicas keep taking local steps in between.  Each replica then holds

    w_i(k+d)  +  ( w̄(k) − w_i(k) )

— the agreed average plus its own local updates from the overlap window, so
the correction never discards local progress (the paper's gradient-delay
compensation, expressed on parameters).

The overlap is **real**, not simulated: the snapshot is an
``overlap=True`` CollectiveOp (``ops.mean_delta_op``), so dispatching it
returns an ``InFlightOp`` handle immediately — the step path never blocks
on the exchange (no host read-back of the probe at the snapshot step), jax
keeps streaming local steps behind it, and the clock records the collective
*off* the critical path (``Timeline`` overlap records; a ``SimulatedClock``
only charges the un-overlapped remainder at fetch time).  Two programs
implement the pair:

* ``sync`` (snapshot)  — dispatches ``mean_delta`` asynchronously; the only
  collective; produces the per-replica correction ``w̄ − w_i`` and the
  variance probe S_k, both *fetched* d steps later.
* ``sync_apply``       — fetches the in-flight op and applies the
  correction: a collective-free elementwise add (donated buffers where
  donation is real).  The probe is reported to the engine as
  ``s_k_at=(snapshot_step, S_k)`` so history and the controller still see
  it attributed to the snapshot iteration.

The in-flight correction is training state: ``state_dict`` fetches it (a
checkpoint is a synchronization point) and rides it under ``_arrays``
together with its probe, due step and snapshot step, so a resumed run
applies the identical correction at the identical iteration and reports the
identical S_k.  A corollary of real overlap: a run *segment* that ends
between a snapshot and its apply has recorded the communication event
(``n_comm_events``) but not yet its probe — the probe belongs to whichever
segment fetches it (a continued ``run()`` or a checkpoint-resumed one), so
``len(history.s_k)`` can trail ``n_syncs`` by the one in-flight exchange,
and consecutive segments' histories always reassemble the uninterrupted
run exactly (tested).  Warmup iterations (``warmup_full_sync_steps``) use
the immediate full sync — the paper overlaps steady-state rounds, not the
period-1 warmup.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.backends.ops import InFlightOp, apply_delta_op, mean_delta_op
from repro.core.controller import ConstantPeriodController
from repro.strategies.base import STEP, SYNC, register_strategy
from repro.strategies.periodic import PeriodicAveragingStrategy

SYNC_APPLY = "sync_apply"
FULL_SYNC = "full_sync"


@register_strategy
class DaSGDStrategy(PeriodicAveragingStrategy):
    """Constant-period averaging applied ``dasgd_delay`` steps late."""

    name = "dasgd"
    controller_cls = ConstantPeriodController

    def __init__(self, cfg, total_steps: int, **kw):
        super().__init__(cfg, total_steps, **kw)
        # keep the overlap window shorter than the averaging period so a
        # new snapshot never lands while one is still in flight
        self.delay = max(1, min(int(cfg.dasgd_delay), max(1, cfg.p_const - 1)))
        self._pending = None          # InFlightOp | fetched (delta, s_k)
        self._apply_at = None         # absolute step the correction is due
        self._snap_at = None          # absolute step the snapshot was taken

    def sync_op(self):
        return mean_delta_op(overlap=True)

    def _build_programs(self, loss_fn, optimizer, backend):
        programs = super()._build_programs(loss_fn, optimizer, backend)
        programs[FULL_SYNC] = programs[SYNC]   # warmup path: immediate sync
        delta_fn = backend.lower(self.sync_op())
        apply_fn = backend.lower(apply_delta_op())

        def snapshot_prog(W, opt_state, batch, lr, key):
            # overlap=True: returns an InFlightOp — nothing here blocks,
            # the collective drains behind the next d local steps
            self._pending = delta_fn(W)
            return W, opt_state, {"overlap_dispatch": True}

        def apply_prog(W, opt_state, batch, lr, key):
            delta, s_k = self._fetch_pending()
            W = apply_fn(W, delta)
            info: Dict[str, Any] = {"delayed_apply": True}
            if s_k is not None and self._snap_at is not None:
                # attribute the probe to the snapshot iteration it measured
                info["s_k_at"] = (self._snap_at, s_k)
            self._pending = None
            self._snap_at = None
            return W, opt_state, info

        programs[SYNC] = snapshot_prog
        programs[SYNC_APPLY] = apply_prog
        return programs

    def _fetch_pending(self):
        p = self._pending
        if isinstance(p, InFlightOp):
            p = p.fetch()
        return p

    def actions(self, k: int):
        acts = [STEP]
        if self._apply_at is not None and k >= self._apply_at:
            acts.append(SYNC_APPLY)
            self._apply_at = None
        if self.controller.sync_now(k):
            if k < self.cfg.warmup_full_sync_steps:
                self._comm_events += 1
                acts.append(FULL_SYNC)
            elif self._apply_at is None:
                self._comm_events += 1
                acts.append(SYNC)
                self._apply_at = k + self.delay
                self._snap_at = k
        return tuple(acts)

    # ------------------------------------------------------------ checkpoint
    def state_dict(self) -> Dict[str, Any]:
        d = super().state_dict()
        d["apply_at"] = self._apply_at
        d["snap_at"] = self._snap_at
        pending = self._fetch_pending()    # a checkpoint is a sync point
        if pending is not None:
            self._pending = pending        # keep the fetched pair live
            delta, s_k = pending
            arrays = d.setdefault("_arrays", {})
            arrays["pending_delta"] = jax.device_get(delta)
            if s_k is not None:
                arrays["pending_s_k"] = jax.device_get(s_k)
        return d

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        super().load_state_dict(state)
        self._apply_at = state.get("apply_at")
        if self._apply_at is not None:
            self._apply_at = int(self._apply_at)
        self._snap_at = state.get("snap_at")
        if self._snap_at is not None:
            self._snap_at = int(self._snap_at)
        arrays = state.get("_arrays") or {}
        if "pending_delta" in arrays:
            pending = arrays["pending_delta"]
            if self.backend is not None:
                pending = self.backend.put_params(pending)
            # pre-overlap checkpoints carry no probe (it was recorded at
            # the snapshot already): apply without re-reporting it
            s_k = arrays.get("pending_s_k")
            if s_k is not None:
                s_k = jnp.asarray(s_k)
            self._pending = (pending, s_k)
        else:
            # no correction in flight (or a legacy checkpoint without one):
            # drop any stale due-step so apply never sees a missing delta
            self._pending = None
            self._apply_at = None
            self._snap_at = None
