"""DaSGD: delayed averaging overlaps the sync with compute (2006.00441).

Synchronous periodic averaging stalls every replica while the all-reduce is
in flight.  DaSGD hides that latency: the average computed from the
parameters at step k is *applied* at step k + d (``cfg.dasgd_delay``), and
replicas keep taking local steps in between.  Each replica then holds

    w_i(k+d)  +  ( w̄(k) − w_i(k) )

— the agreed average plus its own local updates from the overlap window, so
the correction never discards local progress (the paper's gradient-delay
compensation, expressed on parameters).

Two device programs implement the pair:

* ``sync`` (snapshot)  — ``backend.mean_delta()``: the only collective;
  produces the per-replica correction ``w̄ − w_i`` and the variance probe
  S_k, both recorded at the *snapshot* step.
* ``sync_apply``       — ``backend.apply_delta()``: a collective-free
  elementwise add ``d`` steps later.

The in-flight correction is training state: it rides the checkpoint under
``_arrays`` together with its due step, so a resumed run applies it at the
same iteration the uninterrupted run would have.  Warmup iterations
(``warmup_full_sync_steps``) use the immediate full sync — the paper
overlaps steady-state rounds, not the period-1 warmup.
"""
from __future__ import annotations

from typing import Any, Dict

import jax

from repro.configs.base import AveragingConfig
from repro.core.controller import ConstantPeriodController
from repro.strategies.base import STEP, SYNC, register_strategy
from repro.strategies.periodic import PeriodicAveragingStrategy

SYNC_APPLY = "sync_apply"
FULL_SYNC = "full_sync"


@register_strategy
class DaSGDStrategy(PeriodicAveragingStrategy):
    """Constant-period averaging applied ``dasgd_delay`` steps late."""

    name = "dasgd"
    controller_cls = ConstantPeriodController

    def __init__(self, cfg: AveragingConfig, total_steps: int, **kw):
        super().__init__(cfg, total_steps, **kw)
        # keep the overlap window shorter than the averaging period so a
        # new snapshot never lands while one is still in flight
        self.delay = max(1, min(int(cfg.dasgd_delay), max(1, cfg.p_const - 1)))
        self._pending = None          # device pytree: stacked corrections
        self._apply_at = None         # absolute step the correction is due

    def _build_programs(self, loss_fn, optimizer, backend):
        programs = super()._build_programs(loss_fn, optimizer, backend)
        programs[FULL_SYNC] = programs[SYNC]   # warmup path: immediate sync
        delta_fn = backend.mean_delta()
        apply_fn = backend.apply_delta()

        def snapshot_prog(W, opt_state, batch, lr, key):
            self._pending, s_k = delta_fn(W)
            return W, opt_state, {"s_k": s_k}

        def apply_prog(W, opt_state, batch, lr, key):
            W = apply_fn(W, self._pending)
            self._pending = None
            return W, opt_state, {"delayed_apply": True}

        programs[SYNC] = snapshot_prog
        programs[SYNC_APPLY] = apply_prog
        return programs

    def actions(self, k: int):
        acts = [STEP]
        if self._apply_at is not None and k >= self._apply_at:
            acts.append(SYNC_APPLY)
            self._apply_at = None
        if self.controller.sync_now(k):
            if k < self.cfg.warmup_full_sync_steps:
                self._comm_events += 1
                acts.append(FULL_SYNC)
            elif self._apply_at is None:
                self._comm_events += 1
                acts.append(SYNC)
                self._apply_at = k + self.delay
        return tuple(acts)

    # ------------------------------------------------------------ checkpoint
    def state_dict(self) -> Dict[str, Any]:
        d = super().state_dict()
        d["apply_at"] = self._apply_at
        if self._pending is not None:
            d.setdefault("_arrays", {})["pending_delta"] = \
                jax.device_get(self._pending)
        return d

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        super().load_state_dict(state)
        self._apply_at = state.get("apply_at")
        if self._apply_at is not None:
            self._apply_at = int(self._apply_at)
        arrays = state.get("_arrays") or {}
        if "pending_delta" in arrays:
            pending = arrays["pending_delta"]
            if self.backend is not None:
                pending = self.backend.put_params(pending)
            self._pending = pending
        else:
            # no correction in flight (or a legacy checkpoint without one):
            # drop any stale due-step so apply never sees a missing delta
            self._pending = None
            self._apply_at = None
