"""Pluggable communication strategies (see base.py for the API).

Importing this package registers every built-in strategy:
fullsgd / cpsgd / adpsgd / decreasing / qsgd / hier_adpsgd / qsgd_periodic /
adacomm / dasgd.
"""
from repro.strategies.base import (  # noqa: F401
    CommunicationStrategy, available_strategies, comm_stats_for,
    get_strategy_cls, make_strategy, register_strategy,
)
from repro.strategies.periodic import (  # noqa: F401
    AdaptivePeriodStrategy, ConstantPeriodStrategy, DecreasingPeriodStrategy,
    FullSGDStrategy, PeriodicAveragingStrategy,
)
from repro.strategies.quantized import (  # noqa: F401
    QSGDPeriodicStrategy, QSGDStrategy,
)
from repro.strategies.hierarchical import (  # noqa: F401
    HierarchicalADPSGDStrategy,
)
from repro.strategies.adacomm import AdaCommStrategy  # noqa: F401
from repro.strategies.dasgd import DaSGDStrategy  # noqa: F401
