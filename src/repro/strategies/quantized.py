"""Quantized-communication strategies.

``qsgd``          — the paper's every-step baseline (Alistarh et al. 2017):
                    8-bit stochastic gradient quantization, full-frequency
                    communication at qsgd_bits/32 of the FULLSGD volume.
``qsgd_periodic`` — the composition the old string-branched loop could not
                    express: QSGD-quantized *parameter deltas* exchanged on
                    the adaptive periodic-averaging schedule (Algorithm 2),
                    stacking both of the paper's communication savings.

The composed sync keeps a full-precision anchor (the last agreed average);
at each sync every replica quantizes its delta from the anchor, the
dequantized deltas are averaged, and anchor + mean-delta becomes the new
agreed parameter value.  The first sync transmits full precision to seed the
anchor; after that the anchor is training state — it rides the checkpoint
(``state_dict()`` exports it under ``_arrays``) so a resumed run continues
quantized exchanges immediately instead of paying an extra full-precision
reseed sync.  The variance probe S_k is measured on the communicated
(dequantized) deltas, so the adaptive controller sees exactly the statistic
the paper's Algorithm 2 lines 10-11 prescribe.

Both syncs are backend primitives (``backend.all_mean`` /
``backend.quantized_all_mean``), so the quantized exchange lowers to real
collectives on a mesh backend.
"""
from __future__ import annotations

from typing import Any, Dict

import jax

from repro.configs.base import AveragingConfig
from repro.core.comm_model import ring_allreduce_bytes
from repro.core.controller import ADPSGDController
from repro.strategies.base import (STEP, SYNC, CommunicationStrategy,
                                   register_strategy)
from repro.strategies.periodic import PeriodicAveragingStrategy


def qsgd_bytes_per_sync(cfg: AveragingConfig, n_params: int,
                        n_nodes: int) -> float:
    """Quantized levels are not ring-reducible -> the paper charges
    qsgd_bits/32 of the FULLSGD volume with unreduced latency."""
    return ring_allreduce_bytes(n_params, n_nodes) * cfg.qsgd_bits / 32.0


@register_strategy
class QSGDStrategy(CommunicationStrategy):
    """Every-step stochastic gradient quantization (paper §IV baseline)."""

    name = "qsgd"

    def _build_programs(self, loss_fn, optimizer, backend):
        step = backend.qsgd_step(loss_fn, optimizer, self.cfg.qsgd_bits)

        def step_prog(W, opt_state, batch, lr, key):
            W, opt_state, metrics = step(W, opt_state, batch, lr, key)
            return W, opt_state, dict(metrics)

        return {STEP: step_prog}

    def actions(self, k: int):
        self._comm_events += 1
        return (STEP,)

    def comm_bytes_per_sync(self, n_params: int, n_nodes: int) -> float:
        return qsgd_bytes_per_sync(self.cfg, n_params, n_nodes)

    def comm_collective(self) -> str:
        return "gather_bcast"       # not ring-reducible; latency unreduced

    def comm_events_for(self, total_steps: int, n_syncs: int) -> int:
        return total_steps


@register_strategy
class QSGDPeriodicStrategy(PeriodicAveragingStrategy):
    """Quantized deltas on the adaptive periodic schedule (composition)."""

    name = "qsgd_periodic"
    controller_cls = ADPSGDController

    def __init__(self, cfg: AveragingConfig, total_steps: int, **kw):
        super().__init__(cfg, total_steps, **kw)
        self._anchor = None          # full-precision last agreed average

    def _build_programs(self, loss_fn, optimizer, backend):
        programs = super()._build_programs(loss_fn, optimizer, backend)
        full_sync_prog = programs[SYNC]        # parent's full-precision sync
        qsync = backend.quantized_all_mean(self.cfg.qsgd_bits)
        opt_mean = backend.opt_mean() if self.cfg.sync_momentum else None

        def sync_prog(W, opt_state, batch, lr, key):
            if self._anchor is None:
                # seed the anchor: one full-precision sync
                W, opt_state, info = full_sync_prog(W, opt_state, batch, lr, key)
                self._anchor = self.backend.collapse(W)
                return W, opt_state, info
            W, self._anchor, s_k = qsync(W, self._anchor, key)
            if opt_mean is not None and opt_state is not None:
                opt_state = opt_mean(opt_state)
            return W, opt_state, {"s_k": s_k}

        programs[SYNC] = sync_prog
        return programs

    def comm_bytes_per_sync(self, n_params: int, n_nodes: int) -> float:
        return qsgd_bytes_per_sync(self.cfg, n_params, n_nodes)

    def comm_collective(self) -> str:
        return "gather_bcast"

    # ------------------------------------------------------------ checkpoint
    # The anchor is the agreed value every later delta quantizes against —
    # without it a restored run must reseed with a full-precision sync and
    # its trajectory forks from the uninterrupted one.
    def state_dict(self) -> Dict[str, Any]:
        d = super().state_dict()
        if self._anchor is not None:
            d["_arrays"] = {"anchor": jax.device_get(self._anchor)}
        return d

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        super().load_state_dict(state)
        arrays = state.get("_arrays") or {}
        if "anchor" in arrays:
            anchor = arrays["anchor"]
            if self.backend is not None:
                anchor = self.backend.put_replicated(anchor)
            self._anchor = anchor
