"""Quantized-communication strategies.

``qsgd``          — the paper's every-step baseline (Alistarh et al. 2017):
                    8-bit stochastic gradient quantization, full-frequency
                    communication at qsgd_bits/32 of the FULLSGD volume.
``qsgd_periodic`` — the composition the old string-branched loop could not
                    express: QSGD-quantized *parameter deltas* exchanged on
                    the adaptive periodic-averaging schedule (Algorithm 2),
                    stacking both of the paper's communication savings.

The composed sync keeps a full-precision anchor (the last agreed average);
at each sync every replica quantizes its delta from the anchor, the
dequantized deltas are averaged, and anchor + mean-delta becomes the new
agreed parameter value.  The first sync transmits full precision to seed the
anchor.  The variance probe S_k is measured on the communicated
(dequantized) deltas, so the adaptive controller sees exactly the statistic
the paper's Algorithm 2 lines 10-11 prescribe.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import AveragingConfig
from repro.core import averaging as avg
from repro.core import qsgd as qsgd_mod
from repro.core.comm_model import ring_allreduce_bytes
from repro.core.controller import ADPSGDController
from repro.strategies.base import (STEP, SYNC, CommunicationStrategy,
                                   register_strategy)
from repro.strategies.periodic import PeriodicAveragingStrategy


def qsgd_bytes_per_sync(cfg: AveragingConfig, n_params: int,
                        n_nodes: int) -> float:
    """Quantized levels are not ring-reducible -> the paper charges
    qsgd_bits/32 of the FULLSGD volume with unreduced latency."""
    return ring_allreduce_bytes(n_params, n_nodes) * cfg.qsgd_bits / 32.0


@register_strategy
class QSGDStrategy(CommunicationStrategy):
    """Every-step stochastic gradient quantization (paper §IV baseline)."""

    name = "qsgd"

    def _build_programs(self, loss_fn, optimizer):
        step = jax.jit(qsgd_mod.make_qsgd_step(
            loss_fn, optimizer, self.cfg.qsgd_bits))

        def step_prog(W, opt_state, batch, lr, key):
            W, opt_state, metrics = step(W, opt_state, batch, lr, key)
            return W, opt_state, dict(metrics)

        return {STEP: step_prog}

    def actions(self, k: int):
        self._comm_events += 1
        return (STEP,)

    def comm_bytes_per_sync(self, n_params: int, n_nodes: int) -> float:
        return qsgd_bytes_per_sync(self.cfg, n_params, n_nodes)

    def comm_events_for(self, total_steps: int, n_syncs: int) -> int:
        return total_steps


@register_strategy
class QSGDPeriodicStrategy(PeriodicAveragingStrategy):
    """Quantized deltas on the adaptive periodic schedule (composition)."""

    name = "qsgd_periodic"
    controller_cls = ADPSGDController

    def __init__(self, cfg: AveragingConfig, total_steps: int, **kw):
        super().__init__(cfg, total_steps, **kw)
        self._anchor = None          # full-precision last agreed average

    def _build_programs(self, loss_fn, optimizer):
        programs = super()._build_programs(loss_fn, optimizer)
        full_sync_prog = programs[SYNC]        # parent's full-precision sync
        bits = self.cfg.qsgd_bits

        @jax.jit
        def qsync(W, anchor, key):
            R = jax.tree_util.tree_leaves(W)[0].shape[0]
            delta = jax.tree_util.tree_map(
                lambda w, a: w.astype(jnp.float32) - a[None], W, anchor)
            keys = jax.random.split(key, R)
            dq = jax.vmap(
                lambda d, k: qsgd_mod.quantize_pytree(d, k, bits))(delta, keys)
            mean_d = jax.tree_util.tree_map(
                lambda d: jnp.mean(d, axis=0), dq)
            s_k = sum(
                jnp.sum(jnp.square(d - m[None])) / d.shape[0]
                for d, m in zip(jax.tree_util.tree_leaves(dq),
                                jax.tree_util.tree_leaves(mean_d)))
            new_anchor = jax.tree_util.tree_map(
                lambda a, m: a + m, anchor, mean_d)
            W_new = jax.tree_util.tree_map(
                lambda w, a: jnp.broadcast_to(a[None], w.shape).astype(w.dtype),
                W, new_anchor)
            return W_new, new_anchor, s_k

        def sync_prog(W, opt_state, batch, lr, key):
            if self._anchor is None:
                # seed the anchor: one full-precision sync
                W, opt_state, info = full_sync_prog(W, opt_state, batch, lr, key)
                self._anchor = avg.replica_mean(W)
                return W, opt_state, info
            W, self._anchor, s_k = qsync(W, self._anchor, key)
            if self.cfg.sync_momentum and opt_state is not None:
                opt_state = avg.sync_opt_state(opt_state)
            return W, opt_state, {"s_k": s_k}

        programs[SYNC] = sync_prog
        return programs

    def comm_bytes_per_sync(self, n_params: int, n_nodes: int) -> float:
        return qsgd_bytes_per_sync(self.cfg, n_params, n_nodes)
