"""Quantized-communication strategies.

``qsgd``          — the paper's every-step baseline (Alistarh et al. 2017):
                    8-bit stochastic gradient quantization, full-frequency
                    communication at qsgd_bits/32 of the FULLSGD volume.
``qsgd_periodic`` — the composition the old string-branched loop could not
                    express: QSGD-quantized *parameter deltas* exchanged on
                    the adaptive periodic-averaging schedule (Algorithm 2),
                    stacking both of the paper's communication savings.

The composed sync keeps a full-precision anchor (the last agreed average);
at each sync every replica quantizes its delta from the anchor into the
**byte-true wire payload** — int8 levels plus per-tensor norms
(``ops.quantized_all_mean_op``) — which the backend all-gathers and
dequantizes at the receiver; anchor + mean(dequantized deltas) becomes the
new agreed parameter value.  The first sync transmits full precision to
seed the anchor; after that the anchor is training state — it rides the
checkpoint (``state_dict()`` exports it under ``_arrays``) so a resumed run
continues quantized exchanges immediately instead of paying an extra
full-precision reseed sync.  The variance probe S_k is measured on the
communicated (dequantized) deltas, so the adaptive controller sees exactly
the statistic the paper's Algorithm 2 lines 10-11 prescribe.

Both syncs are ``CollectiveOp`` descriptors lowered by the backend, and the
same descriptors price the accounting: the analytic hooks report
qsgd_bits/32 of the FULLSGD volume (the paper's §IV figure, norms
negligible), while the measured wire-byte columns in ``BENCH_engine.json``
include the norm side-channel the byte-true exchange actually moves.
"""
from __future__ import annotations

from typing import Any, Dict

import jax

from repro.backends.ops import (opt_mean_op, qsgd_step_op,
                                quantized_all_mean_op)
from repro.core.controller import ADPSGDController
from repro.strategies.base import (STEP, SYNC, CommunicationStrategy,
                                   register_strategy)
from repro.strategies.periodic import PeriodicAveragingStrategy


@register_strategy
class QSGDStrategy(CommunicationStrategy):
    """Every-step stochastic gradient quantization (paper §IV baseline)."""

    name = "qsgd"

    def step_op(self):
        return qsgd_step_op(self.cfg.qsgd_bits)

    def sync_op(self):
        # the communication event is the fused quantized-gradient step:
        # gather+broadcast (not ring-reducible — latency unreduced) of
        # bits/32 of the volume, the paper's accounting
        return qsgd_step_op(self.cfg.qsgd_bits)

    def _build_programs(self, loss_fn, optimizer, backend):
        step = backend.lower(self.step_op(),
                             loss_fn=loss_fn, optimizer=optimizer)

        def step_prog(W, opt_state, batch, lr, key):
            W, opt_state, metrics = step(W, opt_state, batch, lr, key)
            return W, opt_state, dict(metrics)

        return {STEP: step_prog}

    def actions(self, k: int):
        self._comm_events += 1
        return (STEP,)

    def comm_events_for(self, total_steps: int, n_syncs: int) -> int:
        return total_steps


@register_strategy
class QSGDPeriodicStrategy(PeriodicAveragingStrategy):
    """Quantized deltas on the adaptive periodic schedule (composition)."""

    name = "qsgd_periodic"
    controller_cls = ADPSGDController

    def __init__(self, cfg, total_steps: int, **kw):
        super().__init__(cfg, total_steps, **kw)
        self._anchor = None          # full-precision last agreed average

    def sync_op(self):
        # byte-true anchor-delta exchange: int8 levels + per-tensor norms
        return quantized_all_mean_op(self.cfg.qsgd_bits)

    def _build_programs(self, loss_fn, optimizer, backend):
        programs = super()._build_programs(loss_fn, optimizer, backend)
        full_sync_prog = programs[SYNC]        # parent's full-precision sync
        qsync = backend.lower(self.sync_op())
        opt_mean = (backend.lower(opt_mean_op())
                    if self.cfg.sync_momentum else None)

        def sync_prog(W, opt_state, batch, lr, key):
            if self._anchor is None:
                # seed the anchor: one full-precision sync
                W, opt_state, info = full_sync_prog(W, opt_state, batch, lr, key)
                self._anchor = self.backend.collapse(W)
                return W, opt_state, info
            W, self._anchor, s_k = qsync(W, self._anchor, key)
            if opt_mean is not None and opt_state is not None:
                opt_state = opt_mean(opt_state)
            return W, opt_state, {"s_k": s_k}

        programs[SYNC] = sync_prog
        return programs

    # ------------------------------------------------------------ checkpoint
    # The anchor is the agreed value every later delta quantizes against —
    # without it a restored run must reseed with a full-precision sync and
    # its trajectory forks from the uninterrupted one.
    def state_dict(self) -> Dict[str, Any]:
        d = super().state_dict()
        if self._anchor is not None:
            d["_arrays"] = {"anchor": jax.device_get(self._anchor)}
        return d

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        super().load_state_dict(state)
        arrays = state.get("_arrays") or {}
        if "anchor" in arrays:
            anchor = arrays["anchor"]
            if self.backend is not None:
                anchor = self.backend.put_replicated(anchor)
            self._anchor = anchor
