"""Paper-faithful experiment driver (the paper's §IV at container scale):
train the CIFAR-style CNN with FULLSGD / CPSGD(p=8) / ADPSGD / QSGD /
decreasing-period, reproduce the Figure 1-3 phenomenology and the Table I
accuracy ordering, and print modeled execution times at 100/10 Gbps.

    PYTHONPATH=src python examples/paper_cifar.py [--steps 120]
"""
import argparse
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks import common as C  # noqa: E402
from repro.core.comm_model import GBPS_10, GBPS_100


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=C.TOTAL_STEPS)
    args = ap.parse_args()
    steps = args.steps

    print(f"== {C.N_REPLICAS} workers, batch {C.PER_REPLICA_BATCH}/worker, "
          f"{steps} steps, momentum 0.9, step-decay LR (paper §IV-A) ==\n")

    results = {}
    for method, kw in [("fullsgd", {}), ("cpsgd", dict(p_const=8)),
                       ("adpsgd", {}), ("qsgd", {}),
                       ("decreasing", dict(decreasing=(16, 4))),
                       # beyond-paper strategies via the same registry:
                       ("hier_adpsgd", dict(inner_period=2)),
                       ("qsgd_periodic", {})]:
        h = C.run_method(method, steps=steps, **kw)
        acc = C.eval_accuracy(h)
        results[method] = (h, acc)
        extra = (f" inner={len(h.inner_sync_steps)}"
                 if h.inner_sync_steps else "")
        print(f"{method:13s} loss={np.mean(h.losses[-8:]):.4f} "
              f"acc={acc:.4f} syncs={h.n_syncs:4d}{extra} "
              f"wavg Var[W_k] (Eq.9) = {h.weighted_avg_variance():.3e}")

    ha = results["adpsgd"][0]
    print("\n-- Fig 3: ADPSGD period trajectory --")
    print(" ", ha.period_history)
    print(f"  mean period = {steps / max(1, ha.n_syncs):.2f} "
          f"(paper: ~8.03 on CIFAR)")

    print("\n-- Fig 2: weighted-average variance, ADPSGD vs CPSGD p=8 --")
    wa = ha.weighted_avg_variance()
    wc = results["cpsgd"][0].weighted_avg_variance()
    print(f"  adpsgd={wa:.3e}  cpsgd={wc:.3e}  "
          f"(paper claim: adpsgd smaller -> {wa < wc})")

    print("\n-- Fig 4c: modeled wall-clock (comm model, ring all-reduce) --")
    step_s = ha.wall_s / steps
    for bw, tag in ((GBPS_100, "100Gbps"), (GBPS_10, " 10Gbps")):
        line = [tag]
        tf = None
        for m in ("fullsgd", "qsgd", "cpsgd", "adpsgd"):
            syncs = results[m][0].n_syncs
            cm = C.comm_for(m, C.N_REPLICAS, steps, syncs, bw)
            total = steps * step_s + cm.time_s
            if m == "fullsgd":
                tf = total
            line.append(f"{m}={total:.2f}s({tf / total:.2f}x)")
        print("  " + "  ".join(line))

    print("\n-- Table I ordering check --")
    order = sorted(results, key=lambda m: -results[m][1])
    print("  accuracy ranking:", " > ".join(order))


if __name__ == "__main__":
    main()
