"""End-to-end driver: train a transformer LM with ADPSGD for a few hundred
steps on synthetic data and verify the loss goes down while communication
stays a fraction of full-sync.

    PYTHONPATH=src python examples/train_llm.py --size small --steps 300
    PYTHONPATH=src python examples/train_llm.py --size 100m  --steps 200

``100m`` instantiates a ~109M-parameter llama-style model (12L, d=768,
32k vocab) — the full production path (same model code the dry-run lowers
onto the 256-chip mesh), just on the host device.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import AveragingConfig, ModelConfig
from repro.data.pipeline import SyntheticTokens
from repro.launch.steps import make_loss_fn
from repro.models import model as M
from repro.optim import get_optimizer, make_lr_schedule
from repro.runtime.engine import TrainerEngine

SIZES = {
    "tiny": dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                 d_ff=512, vocab_size=512, seq=64),
    "small": dict(n_layers=4, d_model=256, n_heads=8, n_kv_heads=4,
                  d_ff=1024, vocab_size=2048, seq=128),
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 d_ff=2048, vocab_size=32768, seq=256),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", default="small", choices=SIZES)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--replicas", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    s = SIZES[args.size]
    cfg = ModelConfig(
        name=f"llm-{args.size}", n_layers=s["n_layers"], d_model=s["d_model"],
        n_heads=s["n_heads"], n_kv_heads=s["n_kv_heads"], d_ff=s["d_ff"],
        vocab_size=s["vocab_size"], max_seq_len=s["seq"],
        param_dtype="float32", compute_dtype="float32", remat=False,
        tie_embeddings=True)
    params0 = M.init_params(jax.random.PRNGKey(0), cfg)
    print(f"model: {M.param_count(params0):,} params "
          f"({cfg.n_layers}L d={cfg.d_model} vocab={cfg.vocab_size})")

    data = SyntheticTokens(cfg.vocab_size, s["seq"],
                           n_samples=args.replicas * args.batch * 64)
    engine = TrainerEngine(
        loss_fn=make_loss_fn(cfg),
        optimizer=get_optimizer("adamw"),
        params0=params0,
        n_replicas=args.replicas,
        data_fn=data.batches(n_replicas=args.replicas,
                             per_replica_batch=args.batch),
        lr_fn=make_lr_schedule("cosine", args.lr, args.steps,
                               warmup_steps=min(20, args.steps // 10)),
        avg_cfg=AveragingConfig(method="adpsgd", p_init=2,
                                warmup_full_sync_steps=8,
                                k_sample_frac=0.2),
        total_steps=args.steps,
        track_variance_every=max(1, args.steps // 40),
    )
    t0 = time.time()
    hist = engine.run()
    dt = time.time() - t0
    tok = args.steps * args.replicas * args.batch * s["seq"]
    print(f"{args.steps} steps / {tok:,} tokens in {dt:.0f}s "
          f"({tok / dt:.0f} tok/s on host)")
    print(f"loss {hist.losses[0]:.3f} -> {np.mean(hist.losses[-20:]):.3f}")
    print(f"syncs {hist.n_syncs}/{args.steps} "
          f"(comm reduction {args.steps / max(1, hist.n_syncs):.1f}x); "
          f"periods {hist.period_history[:6]} ... {hist.period_history[-3:]}")
    assert np.mean(hist.losses[-20:]) < hist.losses[0] * 0.9, "did not learn"
    print("OK")


if __name__ == "__main__":
    main()
