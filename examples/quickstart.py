"""Quickstart: train a tiny LM with the paper's ADPSGD (Algorithm 2) across
8 simulated local-SGD workers, and watch the averaging period adapt.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import AveragingConfig, get_config, reduced
from repro.data.pipeline import SyntheticTokens
from repro.launch.steps import make_loss_fn
from repro.models import model as M
from repro.optim import get_optimizer, make_lr_schedule
from repro.runtime.engine import TrainerEngine

STEPS = 100
REPLICAS = 8

cfg = reduced(get_config("olmo-1b").model, n_layers=2, d_model=128,
              vocab_size=256)
data = SyntheticTokens(cfg.vocab_size, seq_len=64, n_samples=2048)
params0 = M.init_params(jax.random.PRNGKey(0), cfg)

# The engine is strategy-agnostic: swap method="adpsgd" for any registered
# strategy (cpsgd / fullsgd / qsgd / hier_adpsgd / qsgd_periodic / ...).
engine = TrainerEngine(
    loss_fn=make_loss_fn(cfg),
    optimizer=get_optimizer("momentum"),
    params0=params0,
    n_replicas=REPLICAS,
    data_fn=data.batches(n_replicas=REPLICAS, per_replica_batch=8),
    lr_fn=make_lr_schedule("step", 0.3, STEPS, decay_steps=(50, 75)),
    avg_cfg=AveragingConfig(method="adpsgd", p_init=2,
                            warmup_full_sync_steps=4, k_sample_frac=0.25),
    total_steps=STEPS,
    track_variance_every=5,
)
hist = engine.run()

print(f"loss: {hist.losses[0]:.3f} -> {np.mean(hist.losses[-10:]):.3f}")
print(f"syncs: {hist.n_syncs}/{STEPS} steps "
      f"(communication reduced {STEPS / max(1, hist.n_syncs):.1f}x "
      f"vs full-sync SGD)")
print(f"adaptive period trajectory: {hist.period_history}")
print(f"variance probe S_k at syncs: "
      f"{['%.2e' % s for s in hist.s_k[:8]]} ...")
assert np.mean(hist.losses[-10:]) < hist.losses[0]
print("OK")
