"""Serving example: batched greedy decoding against KV caches for three
different state kinds (dense GQA, MLA latent cache, hybrid mamba+attention).

    PYTHONPATH=src python examples/serve_llm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.launch.serve import generate
from repro.models import model as M

for arch in ("olmo-1b", "deepseek-v2-lite-16b", "jamba-1.5-large-398b"):
    cfg = reduced(get_config(arch).model)
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    B, S, G = 4, 16, 16
    prompt = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    t0 = time.time()
    out = generate(cfg, params, prompt, gen_len=G)
    dt = time.time() - t0
    assert out.shape == (B, S + G)
    kinds = sorted({k for layer in M.init_caches(cfg, 1, 8)["layers"]
                    for k in layer})
    print(f"{arch:24s} {B * G} tokens in {dt:5.1f}s  "
          f"cache keys: {kinds}")
    print(f"  sample continuation: {np.asarray(out[0, S:S + 8])}")
print("OK")
